//! `lln` — the launcher: train / serve / analyze / experiment runner.
//!
//! All commands run purely from `artifacts/` (built once by
//! `make artifacts`); Python is never on any command's path.

use anyhow::Result;

use lln::cli::{flag, switch, Cli, Command};
use lln::experiments;

fn cli() -> Cli {
    let common = || {
        vec![
            flag("artifacts", "artifacts directory", Some("artifacts")),
            flag("out", "directory for CSV/JSONL outputs", None),
            flag("seed", "RNG seed", Some("0")),
        ]
    };
    Cli {
        bin: "lln",
        about: "Linear Log-Normal Attention — full-system reproduction",
        commands: vec![
            Command {
                name: "exp",
                about: "run a paper experiment (table1|table2|table3|lra|fig1|fig2|fig5|fig6|fig7|fig8|fig10|serve)",
                flags: {
                    let mut f = common();
                    f.extend([
                        flag("steps", "training steps where applicable", None),
                        flag("methods", "comma-separated attention methods", None),
                        flag("method", "single attention method (fig1)", None),
                        flag("lr", "learning rate", None),
                        flag("n", "sequence length for analysis probes", None),
                        flag("d", "head dimension for analysis probes", None),
                        flag("sigma", "input std for fig7", None),
                        flag("trials", "Monte-Carlo trials (fig6)", None),
                        flag("iters", "timing iterations (table2)", None),
                        flag("eval-batches", "held-out eval batches", None),
                        flag("eval-every", "eval interval (fig8)", None),
                        flag("log-every", "log interval (fig8)", None),
                        flag("probe-every", "probe interval (fig1)", None),
                        flag("size", "mlm | tinymlm model size (fig8)", None),
                        flag("heads", "native-path attention heads (fig1)", None),
                        flag("requests", "request count (serve)", None),
                        flag("rate", "offered request rate /s (serve)", None),
                        flag("long-frac", "fraction of long requests (serve)", None),
                        switch("native", "force the native backprop trainer (fig1/fig8)"),
                    ]);
                    f
                },
            },
            Command {
                name: "train",
                about: "MLM pretraining driver (AOT artifact, or native backprop when artifacts are absent / --native)",
                flags: {
                    let mut f = common();
                    f.extend([
                        flag("method", "attention method", Some("lln")),
                        flag("size", "mlm | tinymlm", Some("mlm")),
                        flag("steps", "optimizer steps (default 150)", None),
                        flag("lr", "peak learning rate (default 5e-4)", None),
                        flag("eval-every", "eval interval (default 25)", None),
                        flag("log-every", "log interval (default 10)", None),
                        flag("batch", "native-path batch override (0 = model default)", None),
                        flag("seq", "native-path seqlen override (0 = model default)", None),
                        flag("heads", "native-path attention heads (0 = model default)", None),
                        flag("checkpoint-segments", "native-path gradient-checkpointing segments (0 = off)", None),
                        flag("data-parallel", "native-path data-parallel shards on the compute pool (0 = serial)", None),
                        flag("config", "TOML file with a [train] section (CLI flags override it)", None),
                        flag("checkpoint", "path to write final params", None),
                        switch("native", "backprop through the native backends even when artifacts exist"),
                        switch("check", "exit nonzero unless the final loss beats the first (CI smoke)"),
                    ]);
                    f
                },
            },
            Command {
                name: "serve",
                about: "start the serving coordinator and run a traffic demo",
                flags: {
                    let mut f = common();
                    f.extend([
                        flag("method", "attention method", Some("lln_diag")),
                        flag("methods", "methods to compare", None),
                        flag("requests", "demo request count", Some("100")),
                        flag("rate", "offered req/s", Some("100")),
                        flag("long-frac", "fraction of long requests", Some("0.3")),
                        flag("causal-frac", "fraction of causal (decoder-mask) requests", Some("0")),
                        switch("causal", "serve every request under the causal mask (native path)"),
                        flag("sessions", "concurrent decode sessions to stream (native path)", Some("0")),
                        flag("decode-tokens", "tokens to stream per decode session", Some("48")),
                        flag("shards", "coordinator shards (0 = [serve] config value)", Some("0")),
                        flag("slo-p99", "per-class p99 SLO bound in ms (0 = report only)", Some("0")),
                        flag("chaos-seed", "run the seeded chaos soak instead of the traffic demo (0 = off)", Some("0")),
                        flag("config", "TOML file with [serve] / [compute] sections", None),
                    ]);
                    f
                },
            },
            Command {
                name: "bench",
                about: "run the native kernel perf suite (fused vs pipeline) and record BENCH_kernels.json",
                flags: {
                    let mut f = common();
                    f.extend([
                        flag("json", "write the kernel report to this JSON path", None),
                        flag("baseline", "fail on >25% *_spec regressions vs this BENCH json", None),
                        flag("sizes", "comma-separated sequence lengths", Some("1024,4096,8192")),
                        flag("d", "head dimension", Some("64")),
                        flag("tile", "fused-kernel K/V tile rows (0 = auto)", Some("0")),
                        flag("unroll", "fused-kernel query-row register block (0 = auto)", Some("0")),
                        flag("threads", "worker threads (0 = auto)", Some("0")),
                        switch("full", "full sampling budget (default: quick)"),
                    ]);
                    f
                },
            },
            Command {
                name: "analyze",
                about: "print the paper's core analysis (temperature/entropy/gap/moment matching)",
                flags: {
                    let mut f = common();
                    f.extend([
                        flag("n", "sequence length for analysis probes", None),
                        flag("d", "head dimension for analysis probes", None),
                    ]);
                    f
                },
            },
            Command {
                name: "list",
                about: "list experiments, artifacts, and models",
                flags: common(),
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &lln::cli::Args) -> Result<()> {
    match args.command.as_str() {
        "exp" => {
            let name = args.positional.first().map(String::as_str).unwrap_or("fig2");
            experiments::run(name, args)
        }
        "train" => cmd_train(args),
        "bench" => cmd_bench(args),
        "serve" => experiments::run("serve", args),
        "analyze" => cmd_analyze(args),
        "list" => cmd_list(args),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn cmd_train(args: &lln::cli::Args) -> Result<()> {
    use lln::config::{ConfigTable, TrainConfig};
    use lln::experiments::pretrain::pretrain;
    use lln::runtime::{artifacts_available, artifacts_dir};

    let dir = artifacts_dir(args.get("artifacts"));
    let method = args.get_or("method", "lln").to_string();
    let size = match args.get_or("size", "mlm") {
        "mlm" => "mlm",
        _ => "tinymlm",
    };
    // Precedence: explicit CLI flag > [train] config-file key > the
    // launcher's built-in default (the train flags carry no CLI-side
    // defaults, so an absent flag falls through to the file).
    let file = args
        .get("config")
        .map(|p| -> Result<TrainConfig> {
            let t = ConfigTable::load(std::path::Path::new(p)).map_err(|e| anyhow::anyhow!("{e}"))?;
            Ok(TrainConfig::from_table(&t))
        })
        .transpose()?;
    let f = file.as_ref();
    let steps = args.get_usize("steps", f.map(|c| c.steps).unwrap_or(150))?;
    let native = args.get_bool("native")
        || f.map(|c| c.native).unwrap_or(false)
        || !artifacts_available(&dir);
    let cfg = TrainConfig {
        lr: args.get_f64("lr", f.map(|c| c.lr).unwrap_or(5e-4))?,
        warmup: steps / 10,
        eval_every: args.get_usize("eval-every", f.map(|c| c.eval_every).unwrap_or(25))?,
        log_every: args.get_usize("log-every", f.map(|c| c.log_every).unwrap_or(10))?,
        seed: args.get_usize("seed", 0)? as u64,
        batch: args.get_usize("batch", f.map(|c| c.batch).unwrap_or(0))?,
        seqlen: args.get_usize("seq", f.map(|c| c.seqlen).unwrap_or(0))?,
        heads: args.get_usize("heads", f.map(|c| c.heads).unwrap_or(0))?,
        checkpoint_segments: args
            .get_usize("checkpoint-segments", f.map(|c| c.checkpoint_segments).unwrap_or(0))?,
        data_parallel: args
            .get_usize("data-parallel", f.map(|c| c.data_parallel).unwrap_or(0))?,
        ..Default::default()
    };
    let log_path = args
        .get("out")
        .map(|o| std::path::Path::new(o).join(format!("train_{method}.jsonl")));
    let mode = if native { "native backprop" } else { "AOT artifact" };
    println!("training {size}/{method} for {steps} steps (lr {:.1e}, {mode})", cfg.lr);
    let r = pretrain(&dir, &method, size, steps, &cfg, log_path.as_deref(), native)?;
    let first = r.log.history.first().map(|rec| rec.loss).unwrap_or(f32::NAN);
    let last = r.log.final_loss().unwrap_or(f32::NAN);
    println!(
        "done: loss {first:.3} -> {last:.3}, max grad-norm {:.2}",
        r.log.max_grad_norm()
    );
    if args.get_bool("check") {
        if !(last.is_finite() && first.is_finite() && last < first) {
            anyhow::bail!("training smoke failed: loss did not decrease ({first:.3} -> {last:.3})");
        }
        println!("check passed: final loss beats the first");
    }
    Ok(())
}

fn cmd_bench(args: &lln::cli::Args) -> Result<()> {
    use lln::attention::BackendParams;
    use lln::bench::{run_kernel_bench, Bench};

    let mut sizes = Vec::new();
    for s in args.get_list("sizes", "1024,4096,8192") {
        sizes.push(
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--sizes expects integers, got {s:?}"))?,
        );
    }
    let d = args.get_usize("d", 64)?;
    let params = BackendParams {
        threads: args.get_usize("threads", 0)?,
        tile: args.get_usize("tile", 0)?,
        unroll: args.get_usize("unroll", 0)?,
        ..Default::default()
    };
    let mut b = if args.get_bool("full") { Bench::new() } else { Bench::quick() };
    println!(
        "== kernel perf trajectory (d={d}, {} worker threads, sizes {sizes:?}) ==",
        lln::tensor::resolve_threads(params.threads)
    );
    let report = run_kernel_bench(&mut b, &sizes, d, params);
    println!("\n== derived speedups ==");
    for (fast, slow, n, sp) in report.speedups() {
        println!("{fast:<24} vs {slow:<26} n={n:<6} {sp:.2}x");
    }
    if !report.memory.is_empty() {
        println!("\n== decode-state bytes (d={d}, t={}) ==", report.memory[0].tokens);
        for m in &report.memory {
            println!("{:<24} {:>12} bytes", m.name, m.bytes);
        }
    }
    // Persistent-pool telemetry over the whole suite: every scheduled
    // task is a thread spawn the old scoped kernels would have paid.
    {
        let t = lln::util::compute_pool::telemetry();
        println!("\n== compute pool (workers={}) ==", t.workers);
        println!("{:<24} {:>12}", "spawns_avoided", t.spawns_avoided);
        println!("{:<24} {:>12}", "steals", t.steals);
        println!("{:<24} {:>12}", "parks", t.parks);
        println!("{:<24} {:>12}", "unparks", t.unparks);
    }
    // Read the baseline *before* --json can overwrite the same path
    // (CI passes both flags pointing at the committed file).
    let baseline = match args.get("baseline") {
        Some(path) => Some((
            path.to_string(),
            std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read baseline {path}: {e}"))?,
        )),
        None => None,
    };
    if let Some(path) = args.get("json") {
        report.write_json(std::path::Path::new(path))?;
        println!("\nwrote {path}");
    }
    // CI perf gate: compare the specialized (`*_spec`) rows against a
    // committed BENCH_kernels.json and fail on >25% ns/op regressions.
    // Zero-ns baseline rows (the pre-measurement bootstrap) gate
    // nothing, so the check is safe to run before a perf runner has
    // ever populated the file.
    if let Some((path, baseline)) = baseline {
        let regs = lln::bench::spec_regressions(&report, &baseline, 0.25)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if regs.is_empty() {
            println!("\nbaseline gate: no specialized-kernel regressions vs {path}");
        } else {
            for r in &regs {
                eprintln!("regression: {r}");
            }
            anyhow::bail!("{} specialized kernel row(s) regressed past the 25% gate", regs.len());
        }
    }
    Ok(())
}

fn cmd_analyze(args: &lln::cli::Args) -> Result<()> {
    // A condensed tour of the paper's §3/§4 instruments.
    experiments::run("fig5", args)?;
    println!();
    experiments::run("fig2", args)?;
    Ok(())
}

fn cmd_list(args: &lln::cli::Args) -> Result<()> {
    println!("experiments:");
    for (name, about) in experiments::EXPERIMENTS {
        println!("  {name:<8} {about}");
    }
    let dir = lln::runtime::artifacts_dir(args.get("artifacts"));
    if lln::runtime::artifacts_available(&dir) {
        let m = lln::runtime::Manifest::load(&dir)?;
        println!("\nartifacts ({}):", m.artifacts.len());
        for (name, a) in &m.artifacts {
            println!("  {name:<28} {} in / {} out", a.inputs.len(), a.outputs.len());
        }
        println!("\nmodels ({}):", m.models.len());
        for (tag, spec) in &m.models {
            println!("  {tag:<24} {} params", spec.total_params());
        }
    } else {
        println!("\n(artifacts not built — run `make artifacts`)");
    }
    Ok(())
}
