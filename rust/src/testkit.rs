//! Property-based testing mini-framework (proptest substitute).
//!
//! Deterministic seeded case generation with failure reporting and
//! first-order shrinking: on failure the runner retries with "smaller"
//! regenerated cases (halved size parameter) to report a minimal-ish
//! reproducer seed.
//!
//! ```ignore
//! check(100, |g| {
//!     let xs = g.vec_f32(1..=64, -1.0..=1.0);
//!     prop_assert(xs.len() <= 64, "len bound")
//! });
//! ```

use crate::rng::Pcg64;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assertion helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Case generator handed to property bodies; all draws are deterministic
/// in (seed, case index, size).
pub struct Gen {
    rng: Pcg64,
    /// Soft upper bound used by sized generators; shrinking lowers it.
    pub size: usize,
}

impl Gen {
    fn new(seed: u64, case: u64, size: usize) -> Self {
        Self { rng: Pcg64::new(seed, case), size }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Sized length: in [lo, min(hi, max(lo, size))].
    pub fn len_in(&mut self, lo: usize, hi: usize) -> usize {
        let cap = self.size.max(lo).min(hi);
        self.usize_in(lo, cap)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn gauss_f32(&mut self, std: f32) -> f32 {
        self.rng.normal_f32(0.0, std)
    }

    pub fn vec_f32(&mut self, lo_len: usize, hi_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.len_in(lo_len, hi_len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_gauss(&mut self, lo_len: usize, hi_len: usize, std: f32) -> Vec<f32> {
        let n = self.len_in(lo_len, hi_len);
        (0..n).map(|_| self.gauss_f32(std)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Configuration for the property runner.
#[derive(Clone, Copy, Debug)]
pub struct CheckConfig {
    pub cases: u64,
    pub seed: u64,
    pub start_size: usize,
    /// Shrink attempts after first failure (regeneration at smaller size).
    pub shrink_rounds: usize,
}

impl Default for CheckConfig {
    fn default() -> Self {
        // LLN_PROP_SEED pins the run for reproduction.
        let seed = std::env::var("LLN_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xD15EA5E);
        Self { cases: 64, seed, start_size: 64, shrink_rounds: 12 }
    }
}

/// Run `prop` over `cases` generated inputs; panic with a reproducer on
/// the first failure (after shrink attempts).
pub fn check_with(config: CheckConfig, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..config.cases {
        let mut g = Gen::new(config.seed, case, config.start_size);
        if let Err(msg) = prop(&mut g) {
            // Shrink: re-run the same case stream at smaller sizes.
            let mut best: (usize, String) = (config.start_size, msg);
            let mut size = config.start_size;
            for _ in 0..config.shrink_rounds {
                if size <= 1 {
                    break;
                }
                size /= 2;
                let mut g2 = Gen::new(config.seed, case, size);
                if let Err(m2) = prop(&mut g2) {
                    best = (size, m2);
                }
            }
            panic!(
                "property failed (seed={:#x}, case={}, size={}): {}\n  reproduce with LLN_PROP_SEED={}",
                config.seed, case, best.0, best.1, config.seed
            );
        }
    }
}

/// Run with default configuration and a given case count.
pub fn check(cases: u64, prop: impl Fn(&mut Gen) -> PropResult) {
    check_with(CheckConfig { cases, ..Default::default() }, prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0u64);
        check_with(CheckConfig { cases: 32, ..Default::default() }, |g| {
            let _ = g.u64(0, 10);
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_reproducer() {
        check(16, |g| {
            let v = g.vec_f32(1, 64, 0.0, 1.0);
            prop_assert(v.len() < 8, "vector too long")
        });
    }

    #[test]
    fn generation_is_deterministic() {
        let mut a = Gen::new(1, 0, 64);
        let mut b = Gen::new(1, 0, 64);
        for _ in 0..32 {
            assert_eq!(a.u64(0, 1000), b.u64(0, 1000));
        }
    }

    #[test]
    fn cases_are_distinct() {
        let mut a = Gen::new(1, 0, 64);
        let mut b = Gen::new(1, 1, 64);
        let va: Vec<u64> = (0..8).map(|_| a.u64(0, u64::MAX - 1)).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.u64(0, u64::MAX - 1)).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn ranges_respected() {
        check(64, |g| {
            let x = g.f32_in(-2.0, 3.0);
            prop_assert((-2.0..=3.0).contains(&x), format!("{x} out of range"))?;
            let n = g.len_in(2, 50);
            prop_assert((2..=50).contains(&n), format!("{n} out of range"))
        });
    }
}
