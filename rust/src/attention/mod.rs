//! Native (pure-Rust) implementations of every attention mechanism the
//! paper compares — mirrors `python/compile/kernels/ref.py` numerically.
//!
//! These power the statistical figures (entropy / spectral gap /
//! histograms run over thousands of sampled matrices — far cheaper here
//! than through PJRT), serve as CPU baselines, and cross-check the AOT
//! kernels in integration tests.

pub mod backend;
pub mod decode;
pub mod grad;
pub mod kernels;
pub mod moment_matching;
pub mod paged;

pub use backend::{
    all_backends, backend_for, default_backend, AttentionBackend, AttnCache, AttnGrads,
    BackendParams,
};
pub use decode::{DecodeState, KvCache, PrefixState};
pub use paged::{PageCounters, PagePool, PagedKvCache};
pub use kernels::*;
pub use moment_matching::MomentMatcher;

use crate::tensor::Mat;

/// Matches ref.py's EXP_CLAMP: keeps exp() finite in f32.
pub const EXP_CLAMP: f32 = 30.0;

/// Which (query, key) score pairs a forward pass may use — the mask and
/// scale contract carried by every [`AttentionBackend`] call.
///
/// * `causal` — autoregressive mask: query row `i` attends only to keys
///   `j <= i` (decoder / LM serving).  Requires aligned q/k row indices.
/// * `key_len` — right-padding mask: only keys `j < key_len` are valid
///   (how `lln serve` batches variable-length requests padded up to a
///   bucket).  `None` means every key row is live.
/// * `scale` — score temperature override for the softmax-class
///   kernels; `None` means the usual `1/sqrt(d)`.
///
/// [`AttnSpec::FULL`] reproduces the pre-spec behavior exactly — full
/// bidirectional attention over every key.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttnSpec {
    pub causal: bool,
    pub key_len: Option<usize>,
    pub scale: Option<f32>,
}

impl Default for AttnSpec {
    fn default() -> Self {
        Self::FULL
    }
}

impl AttnSpec {
    /// Full bidirectional attention (the pre-spec default).
    pub const FULL: AttnSpec = AttnSpec { causal: false, key_len: None, scale: None };
    /// Autoregressive attention: row `i` sees keys `j <= i`.
    pub const CAUSAL: AttnSpec = AttnSpec { causal: true, key_len: None, scale: None };

    /// Causal with a right-padding mask (the serving shape: a decoder
    /// request of `key_len` live tokens padded up to its bucket).
    pub fn causal_padded(key_len: usize) -> Self {
        AttnSpec { causal: true, key_len: Some(key_len), scale: None }
    }

    /// Bidirectional with a right-padding mask.
    pub fn padded(key_len: usize) -> Self {
        AttnSpec { causal: false, key_len: Some(key_len), scale: None }
    }

    /// True when no mask is in play (the fast unmasked kernels apply).
    /// A `scale` override is not a mask — callers that only honor the
    /// default scale must check [`AttnSpec::scale`] separately.
    pub fn is_full(&self) -> bool {
        !self.causal && self.key_len.is_none()
    }

    /// Valid key count for a key set of `nk` rows.
    pub fn key_limit(&self, nk: usize) -> usize {
        self.key_len.unwrap_or(nk).min(nk)
    }

    /// How many leading keys query row `i` may attend to.
    pub fn row_limit(&self, i: usize, nk: usize) -> usize {
        let kl = self.key_limit(nk);
        if self.causal {
            kl.min(i + 1)
        } else {
            kl
        }
    }

    /// Score scale for head dim `d` (`1/sqrt(d)` unless overridden).
    pub fn resolve_scale(&self, d: usize) -> f32 {
        self.scale.unwrap_or(1.0 / (d as f32).sqrt())
    }

    /// Total live (query, key) score pairs — the unit the quadratic
    /// flops/memory models charge.  Pure causal on a square n×n problem
    /// gives n(n+1)/2 ≈ half the dense count.
    pub fn masked_pairs(&self, nq: usize, nk: usize) -> f64 {
        let kl = self.key_limit(nk);
        if !self.causal {
            return (nq * kl) as f64;
        }
        // Rows below kl see i+1 keys; rows at/past kl see all kl keys.
        let tri_rows = nq.min(kl) as f64;
        tri_rows * (tri_rows + 1.0) / 2.0 + (nq as f64 - tri_rows) * kl as f64
    }

    /// Fraction of the dense nq×nk score work this spec keeps (1.0 when
    /// unmasked, ~0.5 under pure causal).
    pub fn work_fraction(&self, nq: usize, nk: usize) -> f64 {
        if nq == 0 || nk == 0 {
            1.0
        } else {
            self.masked_pairs(nq, nk) / (nq as f64 * nk as f64)
        }
    }
}

/// Every attention method in the repo (paper Table 1/2 comparisons).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Softmax,
    Lln,
    LlnDiag,
    Elu,
    Relu,
    Quadratic,
    Performer,
    Nystrom,
    BlockDiag,
    Linformer,
}

impl Method {
    pub const ALL: [Method; 10] = [
        Method::Softmax,
        Method::Lln,
        Method::LlnDiag,
        Method::Elu,
        Method::Relu,
        Method::Quadratic,
        Method::Performer,
        Method::Nystrom,
        Method::BlockDiag,
        Method::Linformer,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Softmax => "softmax",
            Method::Lln => "lln",
            Method::LlnDiag => "lln_diag",
            Method::Elu => "elu",
            Method::Relu => "relu",
            Method::Quadratic => "quadratic",
            Method::Performer => "performer",
            Method::Nystrom => "nystrom",
            Method::BlockDiag => "blockdiag",
            Method::Linformer => "linformer",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Memory/compute complexity class in sequence length.
    pub fn is_linear(&self) -> bool {
        !matches!(self, Method::Softmax | Method::Quadratic)
    }

    /// Whether the method can honor causal / key-length masks at all.
    /// Nystrom's segment-mean landmarks and Linformer's sequence-axis
    /// projections mix every position (including future and padding) by
    /// construction, so no per-pair mask exists for them.
    pub fn supports_masking(&self) -> bool {
        !matches!(self, Method::Nystrom | Method::Linformer)
    }

    /// Whether the method's backend can honor this spec exactly.
    pub fn supports_spec(&self, spec: &AttnSpec) -> bool {
        spec.is_full() || self.supports_masking()
    }
}

/// Analytic memory model (bytes) for a single attention head's forward
/// pass — the Table 2 "Memory" column, parameterized like the paper
/// (the stored score matrix is kept for backward, so Softmax/Quadratic
/// charge every *live* score pair here even though the native
/// *inference* forwards now run the fused O(n·tile) kernels).  `n`
/// sequence length, `d` head dim, f32 everywhere.  The [`AttnSpec`]
/// halves the stored-score charge under causal masking and drops the
/// key-side terms for padded `key_len` (only `kl` key/value rows carry
/// state); pass [`AttnSpec::FULL`] for the paper's dense numbers.
pub fn memory_model_bytes(method: Method, n: usize, d: usize, spec: &AttnSpec) -> usize {
    memory_model_bytes_at(method, n, d, spec, crate::lowp::Precision::F32)
}

/// Precision-aware variant of [`memory_model_bytes`]: the at-rest K/V
/// operands are charged at `prec`'s stored row width (payload plus the
/// per-row quant tables at int8-kv), while q, outputs, score tiles,
/// feature maps, and running state stay f32 — mirroring the
/// storage-only contract of the `[compute] precision` knob (operands
/// are decoded to f32 before any arithmetic).  At
/// [`Precision::F32`](crate::lowp::Precision::F32) this is exactly
/// [`memory_model_bytes`].
pub fn memory_model_bytes_at(
    method: Method,
    n: usize,
    d: usize,
    spec: &AttnSpec,
    prec: crate::lowp::Precision,
) -> usize {
    let f = 4; // f32 activations
    let io = 2 * n * d * f + 2 * n * prec.row_bytes(d); // q, out f32; k, v at rest
    let kl = spec.key_limit(n);
    match method {
        // Every live score pair is materialized for backward: n×n when
        // unmasked, n(n+1)/2 under causal, n·kl under padding.
        Method::Softmax | Method::Quadratic => io + spec.masked_pairs(n, n).ceil() as usize * f,
        // Feature maps (q rows + live k rows) + (d x d) state + normalizer.
        Method::Lln | Method::Elu | Method::Relu => io + (n + kl) * d * f + d * d * f + d * f,
        // LLN + the block-diagonal tile stack (masked pairs inside the
        // n/b diagonal b×b tiles).
        Method::LlnDiag => {
            let b = 64.min(n);
            io + (n + kl) * d * f + d * d * f + d * f + blockdiag_tile_bytes(n, b, spec, f)
        }
        Method::BlockDiag => {
            let b = 64.min(n);
            io + blockdiag_tile_bytes(n, b, spec, f)
        }
        // Performer is maskable like the other linear-class methods:
        // q features + live k features + state.
        Method::Performer => io + (n + kl) * d * f + d * d * f,
        // Nystrom/Linformer cannot be masked (Method::supports_masking
        // is false) — their models ignore the spec.
        Method::Nystrom => {
            let m = 32.min(n);
            io + 2 * n * m * f + m * m * f
        }
        Method::Linformer => {
            let k = 64.min(n);
            io + 2 * k * d * f + n * k * f
        }
    }
}

/// Analytic decode-session state bytes after `t` generated tokens at
/// storage precision `prec` — the docs/CONFIG.md decode-sessions
/// table, computed instead of hand-maintained.  Cache-class sessions
/// hold every appended K/V row at the stored row width; BlockDiag
/// holds at most one `block`-row window; the linear class holds the
/// O(d·dv) prefix state, which is always f32 because it is arithmetic
/// state (running sums), not at-rest storage.  `None` for methods with
/// no streaming decode path (Nystrom / Linformer).
pub fn decode_state_model_bytes(
    method: Method,
    t: usize,
    d: usize,
    dv: usize,
    block: usize,
    prec: crate::lowp::Precision,
) -> Option<usize> {
    let f = 4; // f32 prefix state
    let kv_rows = |rows: usize| rows * (prec.row_bytes(d) + prec.row_bytes(dv));
    // Matches PrefixState::state_bytes: state + chunk part + carry.
    let prefix = 3 * (d * dv + d) * f;
    match method {
        Method::Softmax | Method::Quadratic => Some(kv_rows(t)),
        Method::BlockDiag => Some(kv_rows(t.min(block.max(1)))),
        Method::LlnDiag => Some(prefix + kv_rows(t.min(block.max(1)))),
        Method::Lln | Method::Elu | Method::Relu | Method::Performer => Some(prefix),
        Method::Nystrom | Method::Linformer => None,
    }
}

/// Stored bytes of the block-diagonal softmax tile stack under a mask
/// (`f` = bytes per element): the live pairs, costed.
fn blockdiag_tile_bytes(n: usize, b: usize, spec: &AttnSpec, f: usize) -> usize {
    (blockdiag_masked_pairs(n, b, spec) * f as f64).ceil() as usize
}

/// Live (query, key) pairs inside the diagonal b×b tiles of an n-row
/// problem under a spec: each tile keeps only the pairs below its rows'
/// global limits — n·b dense, roughly half that under causal, dead past
/// `key_len`.  Shared by the memory model above and the BlockDiag /
/// LLN+Diag flops models in [`backend`] so the two cost models can
/// never drift apart.
pub(crate) fn blockdiag_masked_pairs(n: usize, block: usize, spec: &AttnSpec) -> f64 {
    let b = block.max(1);
    let mut pairs = 0.0f64;
    let mut b0 = 0;
    // One code path for every spec (an unmasked row's limit is n, so a
    // full tile contributes span² pairs): FULL and the semantically
    // identical padded(n) can never report different costs.
    while b0 < n {
        let span = b.min(n - b0);
        // Live pairs of the tile rows [b0, b0+span): per row i, keys in
        // [b0, b0 + span) clipped by the spec's global row limit.
        for i in b0..b0 + span {
            let lim = spec.row_limit(i, n);
            pairs += lim.saturating_sub(b0).min(span) as f64;
        }
        b0 += span;
    }
    pairs
}

/// Sample Gaussian q, k (and optionally v) with given stds — the probe
/// inputs used throughout §3/§4 analysis.
pub fn gaussian_qkv(
    n: usize,
    d: usize,
    sigma_q: f32,
    sigma_k: f32,
    rng: &mut crate::rng::Pcg64,
) -> (Mat, Mat, Mat) {
    (
        Mat::gaussian(n, d, sigma_q, rng),
        Mat::gaussian(n, d, sigma_k, rng),
        Mat::gaussian(n, d, 1.0, rng),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_round_trip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn memory_model_quadratic_vs_linear() {
        let d = 64;
        let full = AttnSpec::FULL;
        // Quadratic methods blow up 16x when N quadruples; linear ~4x.
        let sm_1k = memory_model_bytes(Method::Softmax, 1024, d, &full) as f64;
        let sm_4k = memory_model_bytes(Method::Softmax, 4096, d, &full) as f64;
        assert!(sm_4k / sm_1k > 10.0);
        let lln_1k = memory_model_bytes(Method::Lln, 1024, d, &full) as f64;
        let lln_4k = memory_model_bytes(Method::Lln, 4096, d, &full) as f64;
        assert!(lln_4k / lln_1k < 5.0);
    }

    #[test]
    fn linear_classification() {
        assert!(!Method::Softmax.is_linear());
        assert!(Method::Lln.is_linear());
        assert!(Method::LlnDiag.is_linear());
    }

    #[test]
    fn spec_row_limits_and_pairs() {
        let full = AttnSpec::FULL;
        assert!(full.is_full());
        assert_eq!(full.key_limit(64), 64);
        assert_eq!(full.row_limit(10, 64), 64);
        assert_eq!(full.masked_pairs(64, 64), 64.0 * 64.0);

        let causal = AttnSpec::CAUSAL;
        assert!(!causal.is_full());
        assert_eq!(causal.row_limit(0, 64), 1);
        assert_eq!(causal.row_limit(63, 64), 64);
        // n(n+1)/2 pairs on a square causal problem.
        assert_eq!(causal.masked_pairs(64, 64), 64.0 * 65.0 / 2.0);
        assert!((causal.work_fraction(4096, 4096) - 0.5).abs() < 1e-3);

        let padded = AttnSpec::padded(40);
        assert_eq!(padded.key_limit(64), 40);
        assert_eq!(padded.row_limit(63, 64), 40);
        assert_eq!(padded.masked_pairs(64, 64), 64.0 * 40.0);

        let cp = AttnSpec::causal_padded(40);
        assert_eq!(cp.row_limit(10, 64), 11);
        assert_eq!(cp.row_limit(50, 64), 40);
        // 40·41/2 triangular pairs + 24 tail rows of 40 keys each.
        assert_eq!(cp.masked_pairs(64, 64), 40.0 * 41.0 / 2.0 + 24.0 * 40.0);

        // key_len larger than the key set clamps.
        assert_eq!(AttnSpec::padded(1000).key_limit(64), 64);
        // Scale override resolution.
        assert_eq!(full.resolve_scale(64), 1.0 / 8.0);
        let scaled = AttnSpec { scale: Some(0.25), ..AttnSpec::FULL };
        assert_eq!(scaled.resolve_scale(64), 0.25);
        assert!(scaled.is_full(), "scale override is not a mask");
    }

    #[test]
    fn memory_model_pinned_points_under_specs() {
        let f = 4usize;
        let (n, d) = (1024usize, 64usize);
        let io = 4 * n * d * f;
        // Softmax, dense: io + n² scores.
        assert_eq!(memory_model_bytes(Method::Softmax, n, d, &AttnSpec::FULL), io + n * n * f);
        // Softmax, causal: io + n(n+1)/2 scores — the causal halving.
        assert_eq!(
            memory_model_bytes(Method::Softmax, n, d, &AttnSpec::CAUSAL),
            io + n * (n + 1) / 2 * f
        );
        // Softmax, padded to 256 live keys: io + n·kl scores.
        assert_eq!(
            memory_model_bytes(Method::Softmax, n, d, &AttnSpec::padded(256)),
            io + n * 256 * f
        );
        // LLN, dense: io + both feature maps + d² state + normalizer.
        assert_eq!(
            memory_model_bytes(Method::Lln, n, d, &AttnSpec::FULL),
            io + 2 * n * d * f + d * d * f + d * f
        );
        // LLN, padded: only kl key-feature rows carry state; causal
        // masking alone changes nothing (every key is processed once).
        assert_eq!(
            memory_model_bytes(Method::Lln, n, d, &AttnSpec::padded(256)),
            io + (n + 256) * d * f + d * d * f + d * f
        );
        assert_eq!(
            memory_model_bytes(Method::Lln, n, d, &AttnSpec::CAUSAL),
            memory_model_bytes(Method::Lln, n, d, &AttnSpec::FULL)
        );
        // BlockDiag, causal: each 64×64 diagonal tile keeps its lower
        // triangle — 65/128 of the dense tile stack.
        let dense_tiles = (n / 64) * 64 * 64 * f;
        let causal_tiles = (n / 64) * (64 * 65 / 2) * f;
        assert_eq!(
            memory_model_bytes(Method::BlockDiag, n, d, &AttnSpec::FULL),
            io + dense_tiles
        );
        assert_eq!(
            memory_model_bytes(Method::BlockDiag, n, d, &AttnSpec::CAUSAL),
            io + causal_tiles
        );
    }

    #[test]
    fn memory_model_precision_narrows_only_kv_terms() {
        use crate::lowp::Precision;
        let (n, d) = (1024usize, 64usize);
        for m in Method::ALL {
            let f32b = memory_model_bytes_at(m, n, d, &AttnSpec::FULL, Precision::F32);
            // The F32 variant IS the default model.
            assert_eq!(f32b, memory_model_bytes(m, n, d, &AttnSpec::FULL), "{m:?}");
            // bf16 halves exactly the 2·n·d·4 at-rest K/V term.
            let bf16 = memory_model_bytes_at(m, n, d, &AttnSpec::FULL, Precision::Bf16);
            assert_eq!(f32b - bf16, 2 * n * d * 2, "{m:?}");
            // int8-kv: 1 byte/elem + 8 bytes/row of scale+zero tables.
            let int8 = memory_model_bytes_at(m, n, d, &AttnSpec::FULL, Precision::Int8Kv);
            assert_eq!(f32b - int8, 2 * n * d * 3 - 2 * n * 8, "{m:?}");
        }
    }

    #[test]
    fn decode_state_model_pinned_points() {
        use crate::lowp::Precision;
        let (t, d, dv, b) = (512usize, 64usize, 64usize, 64usize);
        // Cache class grows with t at the stored row width.
        assert_eq!(
            decode_state_model_bytes(Method::Softmax, t, d, dv, b, Precision::F32),
            Some(t * (d + dv) * 4)
        );
        assert_eq!(
            decode_state_model_bytes(Method::Softmax, t, d, dv, b, Precision::Bf16),
            Some(t * (d + dv) * 2)
        );
        assert_eq!(
            decode_state_model_bytes(Method::Softmax, t, d, dv, b, Precision::Int8Kv),
            Some(t * ((d + dv) + 2 * 8))
        );
        // int8-kv shrinks a cache-class session by more than 2x vs f32.
        let f32b = decode_state_model_bytes(Method::Quadratic, t, d, dv, b, Precision::F32);
        let i8b = decode_state_model_bytes(Method::Quadratic, t, d, dv, b, Precision::Int8Kv);
        assert!(f32b.unwrap() >= 2 * i8b.unwrap());
        // BlockDiag is windowed; the linear class is O(d·dv), t-free
        // and precision-free (prefix state is arithmetic, stays f32).
        assert_eq!(
            decode_state_model_bytes(Method::BlockDiag, t, d, dv, b, Precision::F32),
            Some(b * (d + dv) * 4)
        );
        for p in [Precision::F32, Precision::Int8Kv] {
            assert_eq!(
                decode_state_model_bytes(Method::Lln, t, d, dv, b, p),
                Some(3 * (d * dv + d) * 4)
            );
        }
        assert_eq!(decode_state_model_bytes(Method::Nystrom, t, d, dv, b, Precision::F32), None);
    }

    #[test]
    fn masking_support_classification() {
        for m in Method::ALL {
            assert!(m.supports_spec(&AttnSpec::FULL), "{m:?} must accept full");
            assert_eq!(m.supports_spec(&AttnSpec::CAUSAL), m.supports_masking(), "{m:?}");
        }
        assert!(!Method::Nystrom.supports_masking());
        assert!(!Method::Linformer.supports_masking());
        assert!(Method::Softmax.supports_masking());
        assert!(Method::Lln.supports_masking());
    }
}
