//! Process-wide persistent compute pool — the one scheduler behind
//! every `par_*` kernel (ROADMAP: retire per-call thread spawning).
//!
//! Before this module, each parallel hot path (`Mat::par_matmul{,_t}`,
//! `par_softmax_rows`, the fused attention tiles, the causal chunk
//! recurrence) spawned and joined fresh OS threads per call via
//! `std::thread::scope`, so serving steps and training iterations paid
//! thread-creation latency thousands of times per second.  Here the
//! workers are created once, lazily, and then parked on a condvar
//! between calls; a [`scope`] call costs a handful of mutex pushes and
//! one notify instead of N clone+spawn+join syscalls.
//!
//! Design:
//!
//! * **Per-worker deques with stealing.**  Tasks are pushed round-robin
//!   onto per-worker `Mutex<VecDeque>` deques; a worker pops its own
//!   deque from the front and steals from siblings' backs when empty.
//!   Which worker runs a task never affects the result — see below.
//!
//! * **Caller participation.**  The thread that calls [`scope`] does
//!   not just wait: it drains tasks itself (its own first, then
//!   stealing), and only blocks once every task of its job is either
//!   done or in flight on a worker.  This is the deadlock-freedom
//!   guarantee: a nested [`scope`] call from inside a pool task, or a
//!   hundred coordinator threads calling in concurrently, can always
//!   make progress on their own tasks even if every worker is busy.
//!
//! * **Determinism contract.**  The pool schedules; it never
//!   partitions.  Callers split their output via
//!   [`partition_rows`](crate::tensor::partition_rows) (or the causal
//!   balancer) into disjoint spans, and each span's output is written
//!   only by the task that owns it.  Results are therefore
//!   bitwise-identical regardless of which worker (or the caller)
//!   executes a span, in which order, or how often work was stolen —
//!   only scheduling varies, never the floating-point order.
//!
//! * **Panic propagation.**  A panicking task is caught, the payload
//!   parked on its job, and the panic resumed on the calling thread
//!   once the job drains — the same contract as `std::thread::scope`.
//!
//! Telemetry (spawns avoided, steals, parks/unparks) is exposed via
//! [`telemetry`] and printed by `lln bench`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A lifetime-erased unit of work plus the job it belongs to.
type Unit = (Arc<JobState>, Box<dyn FnOnce() + Send + 'static>);

/// Completion state of one [`scope`] call.
struct JobState {
    /// Units not yet finished (running counts as unfinished).
    pending: AtomicUsize,
    /// Set true when `pending` hits zero; guards the caller's wait.
    done: Mutex<bool>,
    cv: Condvar,
    /// First panic payload from any unit of this job.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct Pool {
    /// One deque per worker; tasks are pushed round-robin and stolen
    /// from the back by idle siblings (and by participating callers).
    deques: Vec<Mutex<VecDeque<Unit>>>,
    /// Round-robin push cursor.
    next_deque: AtomicUsize,
    /// Parking lot: workers sleep here when every deque is empty.  The
    /// mutex guards the empty-check so a push+notify can never race a
    /// worker into a lost wakeup.
    park_mx: Mutex<()>,
    park_cv: Condvar,
    // -- telemetry ---------------------------------------------------
    /// Tasks run through the pool — each one an OS thread spawn the
    /// pre-pool `std::thread::scope` call sites would have paid.
    spawns_avoided: AtomicU64,
    /// Tasks executed from a deque other than the runner's own.
    steals: AtomicU64,
    parks: AtomicU64,
    unparks: AtomicU64,
}

/// Requested worker count for lazy init (0 = available_parallelism).
/// Read once when the pool first spins up; see [`configure`].
static REQUESTED: AtomicUsize = AtomicUsize::new(0);
static POOL: OnceLock<Pool> = OnceLock::new();

/// Set the worker count used when the pool is (lazily) created:
/// `0` means `available_parallelism`.  Wired from
/// `[compute] pool_threads` in config.  A call after the pool has
/// already spun up is a no-op — the pool is process-wide and its
/// workers never shut down.
pub fn configure(threads: usize) {
    REQUESTED.store(threads, Ordering::Relaxed);
}

/// Pool telemetry counters (monotonic since process start).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Telemetry {
    pub workers: usize,
    pub spawns_avoided: u64,
    pub steals: u64,
    pub parks: u64,
    pub unparks: u64,
}

/// Snapshot the pool's telemetry.  Spins the pool up if it has not run
/// anything yet (so `workers` is always the real count).
pub fn telemetry() -> Telemetry {
    let p = pool();
    Telemetry {
        workers: p.deques.len(),
        spawns_avoided: p.spawns_avoided.load(Ordering::Relaxed),
        steals: p.steals.load(Ordering::Relaxed),
        parks: p.parks.load(Ordering::Relaxed),
        unparks: p.unparks.load(Ordering::Relaxed),
    }
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let req = REQUESTED.load(Ordering::Relaxed);
        let n = if req == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            req
        }
        .max(1);
        let pool = Pool {
            deques: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            next_deque: AtomicUsize::new(0),
            park_mx: Mutex::new(()),
            park_cv: Condvar::new(),
            spawns_avoided: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            parks: AtomicU64::new(0),
            unparks: AtomicU64::new(0),
        };
        for wi in 0..n {
            std::thread::Builder::new()
                .name(format!("lln-compute-{wi}"))
                .spawn(move || worker_loop(wi))
                .expect("spawn compute-pool worker");
        }
        pool
    })
}

/// Pop from own deque front, else steal from siblings' backs.
/// `home == usize::MAX` marks a participating caller (no home deque —
/// every unit it takes counts as a steal).
fn take_unit(p: &Pool, home: usize) -> Option<Unit> {
    if home != usize::MAX {
        if let Some(u) = p.deques[home].lock().unwrap().pop_front() {
            return Some(u);
        }
    }
    let n = p.deques.len();
    let start = if home == usize::MAX { 0 } else { home + 1 };
    for off in 0..n {
        let di = (start + off) % n;
        if di == home {
            continue;
        }
        if let Some(u) = p.deques[di].lock().unwrap().pop_back() {
            p.steals.fetch_add(1, Ordering::Relaxed);
            return Some(u);
        }
    }
    None
}

/// Run one unit under `catch_unwind`, park any panic payload on its
/// job, and signal the job's caller when the last unit finishes.
fn run_unit(unit: Unit) {
    let (job, f) = unit;
    if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
        let mut slot = job.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
    if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        let mut done = job.done.lock().unwrap();
        *done = true;
        job.cv.notify_all();
    }
}

fn worker_loop(home: usize) {
    let p = pool();
    loop {
        if let Some(unit) = take_unit(p, home) {
            run_unit(unit);
            continue;
        }
        // Park: re-check emptiness under the park mutex so a
        // concurrent push (which notifies under the same mutex) can
        // never slip between our check and our wait.
        let guard = p.park_mx.lock().unwrap();
        if p.deques.iter().any(|d| !d.lock().unwrap().is_empty()) {
            continue;
        }
        p.parks.fetch_add(1, Ordering::Relaxed);
        drop(p.park_cv.wait(guard).unwrap());
        p.unparks.fetch_add(1, Ordering::Relaxed);
    }
}

/// Execute `tasks` to completion on the persistent pool and return only
/// when every task has finished — the drop-in replacement for a
/// `std::thread::scope` that spawned one thread per task.  Tasks may
/// borrow from the caller's stack (lifetime `'s`): soundness holds
/// because this function blocks until the last task completes (panicked
/// tasks count as complete; their payload is re-thrown here), so no
/// borrow outlives the frame that owns it.
///
/// The caller participates: it drains tasks itself alongside the
/// workers and only sleeps when all of its job's remaining tasks are in
/// flight elsewhere.  Nested calls from inside a pool task are safe for
/// the same reason.
///
/// A single task runs inline with no queue traffic; an empty task list
/// is a no-op.
pub fn scope<'s>(tasks: Vec<Box<dyn FnOnce() + Send + 's>>) {
    match tasks.len() {
        0 => return,
        1 => {
            let mut tasks = tasks;
            (tasks.pop().unwrap())();
            return;
        }
        _ => {}
    }
    let p = pool();
    let job = Arc::new(JobState {
        pending: AtomicUsize::new(tasks.len()),
        done: Mutex::new(false),
        cv: Condvar::new(),
        panic: Mutex::new(None),
    });
    p.spawns_avoided.fetch_add(tasks.len() as u64, Ordering::Relaxed);
    // Erase the borrow lifetime: the blocking wait below guarantees no
    // task (hence no captured borrow) survives this call.
    let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = unsafe { std::mem::transmute(tasks) };
    let n = p.deques.len();
    for f in tasks {
        let di = p.next_deque.fetch_add(1, Ordering::Relaxed) % n;
        p.deques[di].lock().unwrap().push_back((Arc::clone(&job), f));
    }
    {
        // Lock-then-notify pairs with the workers' locked empty-check.
        let _guard = p.park_mx.lock().unwrap();
        p.park_cv.notify_all();
    }
    // Participate: run anything available (own job's tasks drain
    // first in FIFO push order, but any unit keeps the system moving).
    while job.pending.load(Ordering::Acquire) > 0 {
        if let Some(unit) = take_unit(p, usize::MAX) {
            run_unit(unit);
            continue;
        }
        // Everything left of this job is in flight on workers; sleep
        // until the last unit signals.
        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.cv.wait(done).unwrap();
        }
        break;
    }
    if let Some(payload) = job.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
}

/// Run `f(row0, len)` over the
/// [`partition_rows`](crate::tensor::partition_rows) spans of `rows`,
/// scheduled on the pool — the convenience entry point for kernels
/// whose span outputs are reachable through `&self`/indices rather
/// than a `&mut` buffer split.  `threads` is the span-count request
/// (0 = auto via [`resolve_threads`](crate::tensor::resolve_threads));
/// partitioning is deterministic in (`rows`, resolved `threads`) alone,
/// so outputs never depend on pool scheduling.
pub fn scope_rows(rows: usize, threads: usize, f: impl Fn(usize, usize) + Sync) {
    let t = crate::tensor::resolve_threads(threads);
    let spans = crate::tensor::partition_rows(rows, t);
    if spans.len() <= 1 {
        if let Some(&(row0, len)) = spans.first() {
            f(row0, len);
        }
        return;
    }
    let f = &f;
    scope(
        spans
            .into_iter()
            .map(|(row0, len)| Box::new(move || f(row0, len)) as Box<dyn FnOnce() + Send + '_>)
            .collect(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_runs_every_task_once() {
        let n = 64;
        let mut hits = vec![0u8; n];
        {
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut rest = hits.as_mut_slice();
            for _ in 0..n {
                let (one, tail) = std::mem::take(&mut rest).split_at_mut(1);
                rest = tail;
                tasks.push(Box::new(move || one[0] += 1));
            }
            scope(tasks);
        }
        assert!(hits.iter().all(|&h| h == 1), "{hits:?}");
    }

    #[test]
    fn scope_rows_covers_partition_exactly() {
        let rows = 37;
        let seen = Mutex::new(vec![0u8; rows]);
        scope_rows(rows, 5, |row0, len| {
            let mut s = seen.lock().unwrap();
            for r in row0..row0 + len {
                s[r] += 1;
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let total = Mutex::new(0usize);
        scope_rows(8, 4, |_row0, len| {
            // A pool task that itself fans out — the caller-participation
            // contract must drain this without a free worker.
            scope_rows(16, 4, |_r0, l| {
                *total.lock().unwrap() += l * len;
            });
        });
        // Each of the 8 outer rows contributes len * 16.
        assert_eq!(*total.lock().unwrap(), 8 * 16);
    }

    #[test]
    fn panics_propagate_like_thread_scope() {
        let caught = std::panic::catch_unwind(|| {
            scope_rows(8, 4, |row0, _len| {
                if row0 == 0 {
                    panic!("boom from span");
                }
            });
        });
        assert!(caught.is_err());
        // The pool must stay usable after a propagated panic.
        let sum = Mutex::new(0usize);
        scope_rows(6, 3, |_r, l| *sum.lock().unwrap() += l);
        assert_eq!(*sum.lock().unwrap(), 6);
    }

    #[test]
    fn telemetry_counts_scheduled_tasks() {
        let before = telemetry();
        scope_rows(64, 4, |_r, _l| {});
        let after = telemetry();
        assert!(after.workers >= 1);
        assert!(
            after.spawns_avoided >= before.spawns_avoided + 2,
            "multi-span scope must count avoided spawns: {before:?} -> {after:?}"
        );
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        let handles: Vec<_> = (0..8)
            .map(|ci| {
                std::thread::spawn(move || {
                    let acc = Mutex::new(0usize);
                    scope_rows(32, 4, |row0, len| {
                        *acc.lock().unwrap() += (ci + 1) * (row0 + len);
                    });
                    acc.into_inner().unwrap()
                })
            })
            .collect();
        let expect: Vec<usize> = (0..8)
            .map(|ci| {
                crate::tensor::partition_rows(32, 4)
                    .into_iter()
                    .map(|(r, l)| (ci + 1) * (r + l))
                    .sum()
            })
            .collect();
        for (h, e) in handles.into_iter().zip(expect) {
            assert_eq!(h.join().unwrap(), e, "cross-task contamination");
        }
    }
}
