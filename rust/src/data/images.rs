//! Synthetic image dataset for the ViT-lite experiment (Table 3's
//! Dogs-vs-Cats stand-in): 32x32x3 oriented-texture classification.
//!
//! Class 0 = horizontal stripe field, class 1 = vertical, with random
//! frequency, phase, color balance, and additive noise — deciding the
//! class needs integration over many patches (global attention), which
//! is exactly what the paper's ViT experiment exercises.

use crate::rng::Pcg64;

pub const IMG_SIDE: usize = 32;
pub const PATCH: usize = 4;
pub const PATCHES: usize = (IMG_SIDE / PATCH) * (IMG_SIDE / PATCH); // 64
pub const PATCH_DIM: usize = PATCH * PATCH * 3; // 48

/// One ViT batch in the AOT train-step layout: patches (B, P, patch_dim).
#[derive(Clone, Debug)]
pub struct VitBatch {
    pub batch: usize,
    pub patches: Vec<f32>,
    pub labels: Vec<i32>,
}

pub struct ImageGen {
    rng: Pcg64,
    pub noise: f32,
}

impl ImageGen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg64::new(seed, 0x1489), noise: 0.35 }
    }

    /// Render one image as (pixels rgb [0,1], label).
    pub fn image(&mut self) -> (Vec<f32>, i32) {
        let label = self.rng.below(2) as i32;
        let freq = 2.0 + self.rng.f64() * 4.0;
        let phase = self.rng.f64() * std::f64::consts::TAU;
        let tint = [
            0.8 + 0.2 * self.rng.f64(),
            0.8 + 0.2 * self.rng.f64(),
            0.8 + 0.2 * self.rng.f64(),
        ];
        let mut px = Vec::with_capacity(IMG_SIDE * IMG_SIDE * 3);
        for y in 0..IMG_SIDE {
            for x in 0..IMG_SIDE {
                let coord = if label == 0 { y as f64 } else { x as f64 };
                let wave =
                    0.5 + 0.5 * (coord / IMG_SIDE as f64 * freq * std::f64::consts::TAU + phase).sin();
                for c in 0..3 {
                    let noise = (self.rng.f64() - 0.5) * self.noise as f64;
                    px.push(((wave * tint[c] + noise).clamp(0.0, 1.0)) as f32);
                }
            }
        }
        (px, label)
    }

    /// Non-overlapping PATCH x PATCH patchification -> (P, PATCH_DIM).
    pub fn patchify(pixels: &[f32]) -> Vec<f32> {
        let per_row = IMG_SIDE / PATCH;
        let mut out = Vec::with_capacity(PATCHES * PATCH_DIM);
        for p in 0..PATCHES {
            let (py, px_) = (p / per_row, p % per_row);
            for dy in 0..PATCH {
                for dx in 0..PATCH {
                    let y = py * PATCH + dy;
                    let x = px_ * PATCH + dx;
                    let base = (y * IMG_SIDE + x) * 3;
                    out.extend_from_slice(&pixels[base..base + 3]);
                }
            }
        }
        out
    }

    pub fn batch(&mut self, batch: usize) -> VitBatch {
        let mut patches = Vec::with_capacity(batch * PATCHES * PATCH_DIM);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (px, l) = self.image();
            patches.extend(Self::patchify(&px));
            labels.push(l);
        }
        VitBatch { batch, patches, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_consistent() {
        let mut g = ImageGen::new(1);
        let (px, l) = g.image();
        assert_eq!(px.len(), IMG_SIDE * IMG_SIDE * 3);
        assert!(l == 0 || l == 1);
        let p = ImageGen::patchify(&px);
        assert_eq!(p.len(), PATCHES * PATCH_DIM);
        let b = g.batch(4);
        assert_eq!(b.patches.len(), 4 * PATCHES * PATCH_DIM);
        assert_eq!(b.labels.len(), 4);
    }

    #[test]
    fn pixels_in_unit_range() {
        let mut g = ImageGen::new(2);
        let (px, _) = g.image();
        assert!(px.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn orientation_signal_present() {
        // Horizontal stripes: row-wise variance of row means is high,
        // column means nearly constant; vertical is the transpose.
        let mut g = ImageGen::new(3);
        for _ in 0..10 {
            let (px, l) = g.image();
            let lum =
                |y: usize, x: usize| (px[(y * IMG_SIDE + x) * 3] + px[(y * IMG_SIDE + x) * 3 + 1]) / 2.0;
            let row_means: Vec<f64> = (0..IMG_SIDE)
                .map(|y| (0..IMG_SIDE).map(|x| lum(y, x) as f64).sum::<f64>() / IMG_SIDE as f64)
                .collect();
            let col_means: Vec<f64> = (0..IMG_SIDE)
                .map(|x| (0..IMG_SIDE).map(|y| lum(y, x) as f64).sum::<f64>() / IMG_SIDE as f64)
                .collect();
            let var = |v: &[f64]| {
                let m = v.iter().sum::<f64>() / v.len() as f64;
                v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64
            };
            let (rv, cv) = (var(&row_means), var(&col_means));
            if l == 0 {
                assert!(rv > cv, "horizontal image must vary across rows: {rv} vs {cv}");
            } else {
                assert!(cv > rv, "vertical image must vary across cols: {cv} vs {rv}");
            }
        }
    }

    #[test]
    fn patchify_preserves_pixels() {
        let mut g = ImageGen::new(4);
        let (px, _) = g.image();
        let patches = ImageGen::patchify(&px);
        // First patch's first pixel is image (0, 0).
        assert_eq!(patches[0], px[0]);
        // Second patch starts at image (0, 4).
        assert_eq!(patches[PATCH_DIM], px[4 * 3]);
    }
}
