//! Minimal JSON parser + emitter (serde_json substitute).
//!
//! Full RFC 8259 value model; enough performance for multi-MB manifests.
//! Parsing is a single-pass recursive descent over bytes; numbers parse
//! as f64 (the manifest only carries shapes/floats/strings/bools).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes at once.
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    let run = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(run);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"attn_lln_n256","shape":[256,64],"ok":true,"f":0.25}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest_when_present() {
        let path = std::path::Path::new("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}
