//! Tables 4 + 5: LRA-lite — training time/memory per task (Table 4) and
//! task score (Table 5) for SA vs the linear-attention class.
//!
//! Artifact-free degraded mode: with no `artifacts/` directory (or
//! under `--native`), each method trains through the native
//! [`NativeStep`](crate::training::native::NativeStep) classifier
//! instead of erroring out (methods with no native backward are
//! skipped with a note).

use anyhow::Result;

use super::glue::{native_untrainable, train_and_eval_cls, train_and_eval_cls_native};
use super::maybe_write_csv;
use crate::cli::Args;
use crate::data::lra::{LraGen, LraTask, LRA_VOCAB};
use crate::runtime::{artifacts_available, artifacts_dir, Engine};
use crate::util::{current_rss_mb, print_table, Stopwatch};

const METHODS: [&str; 4] = ["softmax", "lln_diag", "performer", "nystrom"];

pub fn run_lra(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let steps = args.get_usize("steps", 120)?;
    let eval_batches = args.get_usize("eval-batches", 15)?;
    let lr = args.get_f64("lr", 1.5e-3)?;
    let methods = args.get_list("methods", &METHODS.join(","));
    let native = args.get_bool("native") || !artifacts_available(&dir);
    let mut engine = if native {
        None
    } else {
        Some(Engine::new(&dir)?)
    };

    let tag = if native { " [native]" } else { "" };
    println!("== Tables 4+5: LRA-lite (N=512, {steps} steps/task, batch 4){tag} ==\n");

    let mut score_rows = Vec::new();
    let mut time_rows = Vec::new();
    let mut csv = Vec::new();
    for method in &methods {
        if native && native_untrainable(method) {
            eprintln!("   [{method}] skipped: no native backward (artifact-only method)");
            continue;
        }
        let artifact = format!("train_lra_{method}");
        let mut scores = Vec::new();
        let mut times = Vec::new();
        let mut mems = Vec::new();
        for task in LraTask::ALL {
            let mut tg = LraGen::new(task, 512, 100);
            let mut eg = LraGen::new(task, 512, 999);
            let mut train_fn = || {
                let b = tg.batch(4);
                (b.tokens, b.labels, 4usize, 512usize)
            };
            let mut eval_fn = || {
                let b = eg.batch(4);
                (b.tokens, b.labels, 4usize, 512usize)
            };
            let rss0 = current_rss_mb();
            let sw = Stopwatch::start();
            let (acc, _gn, _loss) = match engine.as_mut() {
                Some(engine) => train_and_eval_cls(
                    engine,
                    &dir,
                    &artifact,
                    &mut train_fn,
                    &mut eval_fn,
                    steps,
                    eval_batches,
                    lr,
                    10,
                )?,
                None => train_and_eval_cls_native(
                    method,
                    &mut train_fn,
                    &mut eval_fn,
                    steps,
                    eval_batches,
                    lr,
                    LRA_VOCAB,
                    10,
                )?,
            };
            let total = sw.elapsed_secs();
            let mem = (current_rss_mb() - rss0).max(0.0);
            scores.push(acc);
            times.push(total);
            mems.push(mem);
            eprintln!(
                "   [{method}] {}: {:.1}%  ({:.1}s, +{:.0} MB)",
                task.name(),
                acc * 100.0,
                total,
                mem
            );
            csv.push(format!("{method},{},{},{},{}", task.name(), acc * 100.0, total, mem));
        }
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        let mut srow = vec![method.to_string()];
        srow.extend(scores.iter().map(|a| format!("{:.1}", a * 100.0)));
        srow.push(format!("{:.1}", avg * 100.0));
        score_rows.push(srow);
        let mut trow = vec![method.to_string()];
        trow.extend(times.iter().map(|t| format!("{t:.0}")));
        time_rows.push(trow);
    }

    let mut headers: Vec<String> = vec!["method".into()];
    headers.extend(LraTask::ALL.iter().map(|t| t.name().to_string()));
    let mut score_headers = headers.clone();
    score_headers.push("AVG".into());
    println!("\n-- Table 5 analog: LRA-lite score [%] --");
    let hrefs: Vec<&str> = score_headers.iter().map(String::as_str).collect();
    print_table(&hrefs, &score_rows);
    println!("\n-- Table 4 analog: training time [s] --");
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table(&hrefs, &time_rows);
    println!("\npaper shape: LLN+Diag cheapest/fastest of the accurate methods with");
    println!("average score ~ softmax; Performer fast but weaker on some tasks.");
    maybe_write_csv(args, "lra", "method,task,score,secs,mem_mb", &csv)?;
    Ok(())
}
