//! Native-backend serving encoder: the coordinator's PJRT-free compute
//! path, used when AOT artifacts (or the PJRT runtime itself) are
//! unavailable and `ServeConfig::native_fallback` is set.
//!
//! tokens -> deterministic per-(token, position) Gaussian embedding ->
//! one [`AttentionBackend`] forward (q = k = v = embedding) -> mean pool
//! -> fixed seeded linear head -> logits.
//!
//! This is a degraded model (no trained weights), but it exercises the
//! full serving stack — routing, bucketing, dynamic batching, stats,
//! backpressure — with real attention compute, so the coordinator is
//! testable and benchable in environments without artifacts.

use crate::attention::{backend_for, AttentionBackend, BackendParams, Method};
use crate::rng::Pcg64;
use crate::tensor::Mat;

/// Degraded-mode encoder defaults — the native fallback has no model
/// manifest to read these from, so they are fixed and documented here.
pub const NATIVE_D_MODEL: usize = 32;
pub const NATIVE_NUM_CLASSES: usize = 4;
pub const NATIVE_SEED: u64 = 0xC0DE;

/// Largest tile size <= 64 that divides `n` (BlockDiag/LLN+Diag need
/// the sequence length to be a multiple of the tile).
pub fn tile_for(n: usize) -> usize {
    let mut b = n.max(1).min(64);
    while n % b != 0 {
        b -= 1;
    }
    b
}

/// One bucket's native encoder (deterministic in `seed`).
pub struct NativeEncoder {
    backend: Box<dyn AttentionBackend>,
    d_model: usize,
    num_classes: usize,
    head: Mat,
    embed_seed: u64,
}

impl NativeEncoder {
    pub fn new(
        method: Method,
        d_model: usize,
        num_classes: usize,
        seq_len: usize,
        seed: u64,
        compute: &crate::config::ComputeConfig,
    ) -> Self {
        // Honor the configured tile when it divides the bucket length;
        // otherwise fall back to the largest tile that does.
        let block = if compute.block != 0 && seq_len % compute.block == 0 {
            compute.block
        } else {
            tile_for(seq_len)
        };
        let params =
            BackendParams { alpha: 2.0, beta: 2.0, block, ..BackendParams::from_compute(compute) };
        let mut rng = Pcg64::new(seed, 0x4EAD);
        let head = Mat::gaussian(d_model, num_classes, (1.0 / d_model as f32).sqrt(), &mut rng);
        Self { backend: backend_for(method, params), d_model, num_classes, head, embed_seed: seed }
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Deterministic per-(token, position) embedding.
    fn embed(&self, tokens: &[i32]) -> Mat {
        let n = tokens.len();
        let mut x = Mat::zeros(n, self.d_model);
        for (pos, &tok) in tokens.iter().enumerate() {
            let stream = (tok as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ self.embed_seed;
            let mut rng = Pcg64::new(stream, pos as u64);
            rng.fill_gaussian(x.row_mut(pos), 0.0, 0.5);
        }
        x
    }

    /// Logits for one (bucket-padded) token sequence.
    pub fn infer(&self, tokens: &[i32]) -> Vec<f32> {
        let x = self.embed(tokens);
        let out = self.backend.forward(&x, &x, &x);
        let rows = out.rows().max(1);
        let mut pooled = vec![0.0f32; self.d_model];
        for i in 0..out.rows() {
            for (p, &o) in pooled.iter_mut().zip(out.row(i)) {
                *p += o;
            }
        }
        let inv = 1.0 / rows as f32;
        for p in pooled.iter_mut() {
            *p *= inv;
        }
        self.head.matvec_t(&pooled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ComputeConfig;

    #[test]
    fn tile_divides_common_buckets() {
        for n in [32usize, 48, 64, 96, 128, 512] {
            let b = tile_for(n);
            assert!(b >= 1 && b <= 64 && n % b == 0, "n={n} b={b}");
        }
        assert_eq!(tile_for(128), 64);
        assert_eq!(tile_for(96), 48);
    }

    #[test]
    fn infer_is_deterministic_and_finite() {
        let cc = ComputeConfig::default();
        let enc = NativeEncoder::new(Method::LlnDiag, 32, 4, 64, 9, &cc);
        let tokens: Vec<i32> = (0..64).map(|i| (i % 37) + 4).collect();
        let a = enc.infer(&tokens);
        let b = enc.infer(&tokens);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn infer_separates_different_inputs() {
        let cc = ComputeConfig::default();
        let enc = NativeEncoder::new(Method::Lln, 32, 4, 32, 1, &cc);
        let a = enc.infer(&vec![5i32; 32]);
        let b = enc.infer(&vec![6i32; 32]);
        assert_ne!(a, b);
    }

    #[test]
    fn every_method_serves_a_bucket() {
        let cc = ComputeConfig::default();
        for m in Method::ALL {
            let enc = NativeEncoder::new(m, 16, 4, 64, 3, &cc);
            let logits = enc.infer(&vec![7i32; 64]);
            assert_eq!(logits.len(), 4, "{m:?}");
            assert!(logits.iter().all(|x| x.is_finite()), "{m:?}");
        }
    }

    #[test]
    fn configured_compute_knobs_reach_the_backend() {
        // threads=1, chunk=16 and a dividing block must be accepted and
        // still produce the same deterministic logits as defaults (the
        // kernels are parallelism-invariant).
        let custom = ComputeConfig { threads: 1, block: 32, chunk: 16, ..Default::default() };
        let a = NativeEncoder::new(Method::Lln, 32, 4, 64, 9, &custom);
        let b = NativeEncoder::new(Method::Lln, 32, 4, 64, 9, &ComputeConfig::default());
        let tokens: Vec<i32> = (0..64).map(|i| (i % 11) + 4).collect();
        let (la, lb) = (a.infer(&tokens), b.infer(&tokens));
        for (x, y) in la.iter().zip(&lb) {
            assert!((x - y).abs() < 1e-4, "{la:?} vs {lb:?}");
        }
    }

    #[test]
    fn fused_softmax_bucket_matches_materialized_pipeline() {
        // `[compute] fused` flips an exact-softmax bucket between the
        // O(n·tile) streaming kernel and the materialized pipeline; the
        // served logits must agree to kernel tolerance for every tile /
        // unroll configuration a config file could set.
        let tokens: Vec<i32> = (0..96).map(|i| (i % 23) + 4).collect();
        let unfused_cc = ComputeConfig { fused: false, ..Default::default() };
        let reference = NativeEncoder::new(Method::Softmax, 32, 4, 96, 5, &unfused_cc).infer(&tokens);
        for (tile, unroll) in [(0usize, 0usize), (16, 1), (40, 2), (400, 8)] {
            let cc = ComputeConfig { tile, unroll, ..Default::default() };
            let enc = NativeEncoder::new(Method::Softmax, 32, 4, 96, 5, &cc);
            assert_eq!(enc.backend_name(), "softmax");
            let logits = enc.infer(&tokens);
            for (x, y) in logits.iter().zip(&reference) {
                assert!((x - y).abs() < 1e-3, "tile={tile} unroll={unroll}: {logits:?} vs {reference:?}");
            }
        }
    }
}
