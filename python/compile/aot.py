"""AOT exporter: lowers every executable the Rust runtime needs to HLO
*text* plus a JSON manifest, and dumps initial parameters as raw f32/i32
binaries.

Interchange is HLO text (NOT serialized HloModuleProto): jax >= 0.5
emits 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifact sets (--sets to select; default all):
  micro  — single-head attention kernels across sequence lengths
           (Table 2 scaling, quickstart)
  tiny   — tiny-model train/eval steps for integration tests
  glue   — Table 1 classification train/eval steps (6 methods)
  lra    — Tables 4/5 LRA-lite train/eval steps (N=512)
  vit    — Table 3 ViT-lite train/eval steps (patch mode)
  mlm    — fig 8/9 pretraining train/eval steps ("small" model)
  probe  — fig 1 attention-matrix probe executables
  serve  — serving-path encoder forwards (batcher bucket shapes)

Python runs ONCE: `make artifacts` is incremental (skips artifacts whose
file already exists unless --force).

Everything an executable needs to be called from Rust is in
manifest.json: flat input order/shapes/dtypes, output order, the
canonical parameter order, moment-matching constants, and model configs.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from . import moment_matching as mm
from .kernels import autodiff as att
from .kernels import ref

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(x) -> str:
    return I32 if np.issubdtype(np.asarray(x).dtype, np.integer) else F32


@dataclasses.dataclass
class Artifact:
    name: str
    file: str
    inputs: list          # [{name, shape, dtype}]
    outputs: list         # [{name, shape, dtype}]
    meta: dict


class Exporter:
    def __init__(self, out_dir: str, force: bool = False):
        self.out_dir = out_dir
        self.force = force
        self.artifacts: list[Artifact] = []
        self.models: dict[str, dict] = {}
        self.mm_a, self.mm_b = self._mm_constants()
        os.makedirs(out_dir, exist_ok=True)

    # -- moment matching constants (cached on disk; fit is stochastic) ------
    def _mm_constants(self):
        cache = os.path.join(self.out_dir, "mm_constants.json")
        if os.path.exists(cache):
            d = json.load(open(cache))
            return d["a"], d["b"]
        print("[aot] fitting moment-matching constants (a, b)...", flush=True)
        a, b = mm.fit_broad_constants()
        os.makedirs(self.out_dir, exist_ok=True)
        json.dump({"a": a, "b": b}, open(cache, "w"))
        return a, b

    # -- core: lower fn at example args and record manifest entry -----------
    def export(self, name, fn, in_specs, in_names, out_names, meta=None):
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        if os.path.exists(path) and not self.force:
            # Still need shapes for the manifest: recompute via eval_shape.
            out_shapes = jax.eval_shape(fn, *in_specs)
            self._record(name, fname, in_specs, in_names, out_names, out_shapes, meta)
            print(f"[aot] {name}: exists, manifest only", flush=True)
            return
        t0 = time.time()
        # keep_unused=True: the compiled signature must match the manifest
        # exactly even when an executable doesn't touch some parameter
        # (e.g. mlm.bias in a classification eval) — the Rust runtime
        # always feeds the full canonical parameter set.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *in_specs)
        self._record(name, fname, in_specs, in_names, out_names, out_shapes, meta)
        print(f"[aot] {name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s", flush=True)

    def _record(self, name, fname, in_specs, in_names, out_names, out_shapes, meta):
        flat_in = jax.tree_util.tree_leaves(in_specs)
        flat_out = jax.tree_util.tree_leaves(out_shapes)
        assert len(flat_in) == len(in_names), f"{name}: {len(flat_in)} inputs vs {len(in_names)} names"
        assert len(flat_out) == len(out_names), f"{name}: {len(flat_out)} outputs vs {len(out_names)} names"
        ins = [
            {"name": nm, "shape": list(s.shape), "dtype": I32 if s.dtype == jnp.int32 else F32}
            for nm, s in zip(in_names, flat_in)
        ]
        outs = [
            {"name": nm, "shape": list(s.shape), "dtype": I32 if s.dtype == jnp.int32 else F32}
            for nm, s in zip(out_names, flat_out)
        ]
        self.artifacts.append(Artifact(name, fname, ins, outs, meta or {}))

    # -- parameter binaries --------------------------------------------------
    def export_params(self, tag: str, cfg: M.ModelConfig, seed=0, patch_dim=None):
        params = M.init_params(cfg, seed=seed, patch_dim=patch_dim)
        order = M.param_order(params)
        fname = f"params_{tag}.bin"
        path = os.path.join(self.out_dir, fname)
        if not (os.path.exists(path) and not self.force):
            with open(path, "wb") as f:
                for k in order:
                    f.write(np.ascontiguousarray(params[k]).tobytes())
        self.models[tag] = {
            "config": {k: v for k, v in dataclasses.asdict(cfg).items()},
            "patch_dim": patch_dim,
            "params_file": fname,
            "param_order": order,
            "param_shapes": {k: list(params[k].shape) for k in order},
        }
        return params, order

    def finish(self):
        """Write manifest.json, merging with any existing manifest so a
        partial `--sets` run never drops previously-exported entries."""
        path = os.path.join(self.out_dir, "manifest.json")
        models = dict(self.models)
        arts = {a.name: dataclasses.asdict(a) for a in self.artifacts}
        if os.path.exists(path):
            old = json.load(open(path))
            for tag, m in old.get("models", {}).items():
                models.setdefault(tag, m)
            for a in old.get("artifacts", []):
                arts.setdefault(a["name"], a)
        manifest = {
            "mm_a": self.mm_a,
            "mm_b": self.mm_b,
            "models": models,
            "artifacts": sorted(arts.values(), key=lambda a: a["name"]),
        }
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"[aot] manifest: {len(arts)} artifacts, {len(models)} models")


# ---------------------------------------------------------------------------
# Artifact set builders
# ---------------------------------------------------------------------------

MICRO_D = 64
# Paper Table 2 sweeps 512..16384; SA capped at 4096 (the paper's OOM
# analog: quadratic interpret-mode cost, documented in EXPERIMENTS.md).
MICRO_NS_LINEAR = (256, 1024, 4096, 8192, 16384)
MICRO_NS_QUAD = (256, 1024, 4096)


def build_micro(ex: Exporter):
    d = MICRO_D
    for n in MICRO_NS_QUAD:
        qkv = [spec((n, d)) for _ in range(3)]
        ex.export(
            f"attn_softmax_n{n}",
            lambda q, k, v: (att.softmax_attention(q, k, v),),
            qkv, ["q", "k", "v"], ["out"], {"method": "softmax", "n": n, "d": d},
        )
    for n in MICRO_NS_LINEAR:
        qkv = [spec((n, d)) for _ in range(3)]
        ab = [spec(()), spec(())]
        # Perf (EXPERIMENTS.md §Perf L1): interpret-mode cost is dominated
        # by per-grid-step overhead, so linear-kernel chunk sizes scale
        # with N (math-equivalent — the kernel reduces over chunks).
        # On TPU the same knob trades VMEM residency for DMA count.
        blk = 1024 if n >= 4096 else 128
        ex.export(
            f"attn_lln_n{n}",
            lambda q, k, v, a, b: (att.lln_attention(q, k, v, a, b, block_q=blk, block_k=blk),),
            qkv + ab, ["q", "k", "v", "alpha", "beta"], ["out"],
            {"method": "lln", "n": n, "d": d},
        )
        ex.export(
            f"attn_lln_diag_n{n}",
            lambda q, k, v, a, b: (
                att.lln_diag_attention(q, k, v, a, b, 64, block_q=blk, block_k=blk),
            ),
            qkv + ab, ["q", "k", "v", "alpha", "beta"], ["out"],
            {"method": "lln_diag", "n": n, "d": d},
        )
        ex.export(
            f"attn_elu_n{n}",
            lambda q, k, v: (att.elu_attention(q, k, v, block_q=blk, block_k=blk),),
            qkv, ["q", "k", "v"], ["out"], {"method": "elu", "n": n, "d": d},
        )
        proj = jnp.asarray(np.random.default_rng(0).normal(size=(d, d)), jnp.float32)
        ex.export(
            f"attn_performer_n{n}",
            lambda q, k, v: (ref.performer_attention(q, k, v, proj),),
            qkv, ["q", "k", "v"], ["out"], {"method": "performer", "n": n, "d": d},
        )
        ex.export(
            f"attn_nystrom_n{n}",
            lambda q, k, v: (ref.nystrom_attention(q, k, v, 32),),
            qkv, ["q", "k", "v"], ["out"], {"method": "nystrom", "n": n, "d": d},
        )


def _train_io_names(order, extra_in, extra_out):
    ins = (
        [f"p:{k}" for k in order]
        + [f"m:{k}" for k in order]
        + [f"v:{k}" for k in order]
        + ["t", "lr"]
        + extra_in
    )
    outs = (
        [f"p:{k}" for k in order]
        + [f"m:{k}" for k in order]
        + [f"v:{k}" for k in order]
        + ["loss", "grad_norm", "layer_stats"]
        + extra_out
    )
    return ins, outs


def _export_train_cls(ex, name_prefix, tag, cfg, batch, seqlen):
    params, order = ex.export_params(tag, cfg)
    pspecs = {k: spec(params[k].shape) for k in order}
    base = [pspecs, pspecs, pspecs, spec(()), spec(())]
    tok = spec((batch, seqlen), jnp.int32)
    lab = spec((batch,), jnp.int32)
    ins, outs = _train_io_names(order, ["tokens", "labels"], [])
    ex.export(
        f"{name_prefix}",
        lambda p, m, v, t, lr, tokens, labels: T.train_step_cls(p, m, v, t, lr, tokens, labels, cfg),
        base + [tok, lab], ins, outs,
        {"model": tag, "kind": "train_cls", "batch": batch, "seqlen": seqlen},
    )
    ex.export(
        f"{name_prefix.replace('train', 'eval')}",
        lambda p, tokens: T.eval_cls(p, tokens, cfg),
        [pspecs, tok], [f"p:{k}" for k in order] + ["tokens"], ["logits"],
        {"model": tag, "kind": "eval_cls", "batch": batch, "seqlen": seqlen},
    )


def build_glue(ex: Exporter):
    """Table 1: six methods on the GLUE-like synthetic suite."""
    for method in ("softmax", "lln", "lln_diag", "elu", "performer", "nystrom"):
        cfg = M.make_config(
            "tiny", vocab_size=512, d_model=128, n_heads=4, n_layers=3, d_ff=512,
            max_len=128, num_classes=4, attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b,
        )
        _export_train_cls(ex, f"train_glue_{method}", f"glue_{method}", cfg, batch=16, seqlen=128)


def build_lra(ex: Exporter):
    """Tables 4/5: LRA-lite at N=512 (byte-level vocab)."""
    for method in ("softmax", "lln_diag", "performer", "nystrom"):
        cfg = M.make_config(
            "tiny", vocab_size=260, d_model=128, n_heads=4, n_layers=2, d_ff=512,
            max_len=512, num_classes=10, attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b,
        )
        _export_train_cls(ex, f"train_lra_{method}", f"lra_{method}", cfg, batch=4, seqlen=512)


def build_vit(ex: Exporter):
    """Table 3: ViT-lite on 32x32x3 images as 64 patches of dim 48."""
    patch_dim, patches = 48, 64
    for method in ("softmax", "lln_diag", "linformer"):
        cfg = M.make_config(
            "tiny", vocab_size=32, d_model=128, n_heads=4, n_layers=4, d_ff=512,
            max_len=patches, num_classes=2, attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b,
            diag_block=16,
        )
        tag = f"vit_{method}"
        params, order = ex.export_params(tag, cfg, patch_dim=patch_dim)
        pspecs = {k: spec(params[k].shape) for k in order}
        base = [pspecs, pspecs, pspecs, spec(()), spec(())]
        px = spec((16, patches, patch_dim))
        lab = spec((16,), jnp.int32)
        ins, outs = _train_io_names(order, ["patches", "labels"], [])
        ex.export(
            f"train_vit_{method}",
            lambda p, m, v, t, lr, patches_, labels: T.train_step_vit(p, m, v, t, lr, patches_, labels, cfg),
            base + [px, lab], ins, outs,
            {"model": tag, "kind": "train_vit", "batch": 16, "seqlen": patches},
        )
        ex.export(
            f"eval_vit_{method}",
            lambda p, patches_: T.eval_vit(p, patches_, cfg),
            [pspecs, px], [f"p:{k}" for k in order] + ["patches"], ["logits"],
            {"model": tag, "kind": "eval_vit", "batch": 16, "seqlen": patches},
        )


def _export_train_mlm(ex, name, tag, cfg, batch, seqlen):
    params, order = ex.export_params(tag, cfg)
    pspecs = {k: spec(params[k].shape) for k in order}
    base = [pspecs, pspecs, pspecs, spec(()), spec(())]
    tok = spec((batch, seqlen), jnp.int32)
    lab = spec((batch, seqlen), jnp.int32)
    w = spec((batch, seqlen))
    ins, outs = _train_io_names(order, ["tokens", "labels", "weights"], [])
    ex.export(
        name,
        lambda p, m, v, t, lr, tokens, labels, weights: T.train_step_mlm(
            p, m, v, t, lr, tokens, labels, weights, cfg
        ),
        base + [tok, lab, w], ins, outs,
        {"model": tag, "kind": "train_mlm", "batch": batch, "seqlen": seqlen},
    )
    ex.export(
        name.replace("train", "eval"),
        lambda p, tokens, labels, weights: T.eval_mlm(p, tokens, labels, weights, cfg),
        [pspecs, tok, lab, w],
        [f"p:{k}" for k in order] + ["tokens", "labels", "weights"], ["loss"],
        {"model": tag, "kind": "eval_mlm", "batch": batch, "seqlen": seqlen},
    )


def build_tiny(ex: Exporter):
    """Integration-test models: fast to compile, fast to run."""
    for method in ("softmax", "lln", "lln_diag", "elu"):
        cfg = M.make_config("tiny", attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b)
        _export_train_mlm(ex, f"train_tinymlm_{method}", f"tinymlm_{method}", cfg, batch=4, seqlen=128)


def build_mlm(ex: Exporter):
    """Fig 8/9: the end-to-end pretraining model ("small": ~5M params)."""
    for method in ("softmax", "lln", "lln_diag"):
        cfg = M.make_config("small", max_len=128, attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b)
        _export_train_mlm(ex, f"train_mlm_{method}", f"mlm_{method}", cfg, batch=8, seqlen=128)


def build_probe(ex: Exporter):
    """Fig 1: per-layer attention matrices + stats on the MLM models."""
    for method in ("softmax", "lln"):
        tag = f"mlm_{method}"
        cfg = M.make_config("small", max_len=128, attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b)
        if tag not in ex.models:
            ex.export_params(tag, cfg)
        order = ex.models[tag]["param_order"]
        shapes = ex.models[tag]["param_shapes"]
        pspecs = {k: spec(tuple(shapes[k])) for k in order}
        tok = spec((2, 128), jnp.int32)
        ex.export(
            f"probe_{method}",
            lambda p, tokens: M.attention_probe(p, tokens, cfg),
            [pspecs, tok], [f"p:{k}" for k in order] + ["tokens"],
            ["attn_matrices", "layer_stats"],
            {"model": tag, "kind": "probe", "batch": 2, "seqlen": 128},
        )


def build_fig10(ex: Exporter):
    """Fig 10 ablation: fixed alpha = beta grid on the SST2-like task."""
    for alpha in (0.5, 1.0, 2.0, 3.0, 4.0):
        tag_a = str(alpha).replace(".", "p")
        cfg = M.make_config(
            "tiny", vocab_size=512, d_model=128, n_heads=4, n_layers=3, d_ff=512,
            max_len=128, num_classes=4, attn="lln", mm_a=ex.mm_a, mm_b=ex.mm_b,
            fixed_alpha=alpha, fixed_beta=alpha,
        )
        _export_train_cls(
            ex, f"train_fig10_a{tag_a}", f"fig10_a{tag_a}", cfg, batch=16, seqlen=128
        )


def build_serve(ex: Exporter):
    """Serving-path forwards at the batcher's bucket shapes."""
    for method in ("softmax", "lln_diag"):
        tag = f"glue_{method}"
        cfg = M.make_config(
            "tiny", vocab_size=512, d_model=128, n_heads=4, n_layers=3, d_ff=512,
            max_len=512, num_classes=4, attn=method, mm_a=ex.mm_a, mm_b=ex.mm_b,
        )
        stag = f"serve_{method}"
        params, order = ex.export_params(stag, cfg)
        pspecs = {k: spec(params[k].shape) for k in order}
        for batch in (1, 8):
            for n in (128, 512):
                tok = spec((batch, n), jnp.int32)
                ex.export(
                    f"serve_{method}_b{batch}_n{n}",
                    lambda p, tokens: T.eval_cls(p, tokens, cfg),
                    [pspecs, tok], [f"p:{k}" for k in order] + ["tokens"], ["logits"],
                    {"model": stag, "kind": "serve", "batch": batch, "seqlen": n},
                )


SETS = {
    "micro": build_micro,
    "tiny": build_tiny,
    "glue": build_glue,
    "lra": build_lra,
    "vit": build_vit,
    "mlm": build_mlm,
    "probe": build_probe,
    "fig10": build_fig10,
    "serve": build_serve,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sets", default=",".join(SETS), help="comma-separated artifact sets")
    ap.add_argument("--force", action="store_true", help="re-lower even if files exist")
    args = ap.parse_args()

    ex = Exporter(args.out, force=args.force)
    for s in args.sets.split(","):
        s = s.strip()
        if not s:
            continue
        if s not in SETS:
            print(f"unknown set {s!r}; known: {list(SETS)}", file=sys.stderr)
            sys.exit(2)
        print(f"[aot] === building set {s} ===", flush=True)
        SETS[s](ex)
    ex.finish()


if __name__ == "__main__":
    main()
