//! Serving demo: start the coordinator (router + dynamic batcher +
//! PJRT workers) with LLN+Diag encoders and drive mixed-length traffic.
//!
//!     make artifacts && cargo run --release --example serve -- [requests]

use anyhow::Result;

use lln::config::ServeConfig;
use lln::coordinator::Coordinator;
use lln::data::tasks::{GlueGen, GlueTask};
use lln::rng::Pcg64;
use lln::runtime::artifacts_dir;

fn main() -> Result<()> {
    let requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let dir = artifacts_dir(None);
    let cfg = ServeConfig::default();
    println!(
        "starting coordinator: method={} buckets={:?} max_batch={} queue={}",
        cfg.method, cfg.buckets, cfg.max_batch, cfg.queue_capacity
    );
    let coord = Coordinator::start(cfg, &dir)?;
    // Warm both buckets (first call compiles the executables).
    coord.infer(vec![lln::data::special::CLS; 64])?;
    coord.infer(vec![lln::data::special::CLS; 300])?;
    println!("warmed up; sending {requests} requests (70% short / 30% long)...");

    let mut short = GlueGen::new(GlueTask::Sst2, 512, 120, 1);
    let mut long = GlueGen::new(GlueTask::Qnli, 512, 480, 2);
    let mut rng = Pcg64::seed(0);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|_| {
            let tokens = if rng.f64() < 0.3 { long.example().0 } else { short.example().0 };
            coord.submit(tokens)
        })
        .collect::<Result<_>>()?;
    let mut ok = 0usize;
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.result.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats_arc = coord.stats();
    let st = stats_arc.lock().unwrap();
    println!("\ncompleted {ok}/{requests} in {wall:.2}s  ({:.1} req/s)", ok as f64 / wall);
    println!(
        "latency p50 {:.1} ms  p95 {:.1} ms   mean batch {:.2}   rejected {}",
        st.p50_latency(),
        st.p95_latency(),
        st.mean_batch_size(),
        st.rejected
    );
    drop(st);
    coord.shutdown();
    println!("serve demo OK");
    Ok(())
}
