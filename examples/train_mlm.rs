//! End-to-end validation driver (DESIGN.md deliverable): pretrain the
//! RoBERTa-lite MLM model with BOTH softmax and LLN attention on the
//! synthetic corpus, and report the fig-8-style loss comparison.
//! Steps run through the AOT train artifacts when `artifacts/` exists
//! (`make artifacts`), else through the native backprop trainer — the
//! fig. 8 pipeline no longer needs artifacts at all.
//!
//!     cargo run --release --example train_mlm -- [steps]
//!
//! The run is recorded in EXPERIMENTS.md §Fig8.

use anyhow::Result;

use lln::config::TrainConfig;
use lln::experiments::pretrain::pretrain;
use lln::runtime::artifacts_dir;
use lln::training::metrics::sparkline;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let dir = artifacts_dir(None);
    let cfg = TrainConfig {
        lr: 5e-4,
        warmup: steps / 10,
        eval_every: (steps / 6).max(1),
        log_every: (steps / 10).max(1),
        ..Default::default()
    };

    println!("== end-to-end MLM pretraining ({steps} steps, small model, B=8 N=128) ==");
    let mut results = Vec::new();
    for method in ["softmax", "lln"] {
        println!("\n--- {method} ---");
        let out = std::path::Path::new("runs").join(format!("train_mlm_{method}.jsonl"));
        let r = pretrain(&dir, method, "mlm", steps, &cfg, Some(&out), false)?;
        println!("   metrics -> {}", out.display());
        results.push(r);
    }

    println!("\n== fig 8 analog: training loss ==");
    for r in &results {
        let series: Vec<f64> = r.log.history.iter().map(|x| x.loss as f64).collect();
        println!(
            "{:>8} {}  {:.3} -> {:.3}",
            r.method,
            sparkline(&series, 56),
            series.first().unwrap(),
            series.last().unwrap()
        );
    }
    println!("\n== held-out eval loss ==");
    for r in &results {
        let pts: Vec<String> =
            r.eval_losses.iter().map(|(s, l)| format!("{s}:{l:.3}")).collect();
        println!("{:>8}  {}", r.method, pts.join("  "));
    }
    let sm = results[0].eval_losses.last().unwrap().1;
    let ll = results[1].eval_losses.last().unwrap().1;
    println!("\nfinal eval loss: softmax {sm:.3} vs lln {ll:.3} (paper: curves track closely)");
    for r in &results {
        if let Some((_, a0)) = r.alpha_series.first() {
            let an = r.alpha_series.last().unwrap().1;
            println!("fig 9 ({}): alpha {a0:.2} -> {an:.2} over training", r.method);
        }
    }
    Ok(())
}
