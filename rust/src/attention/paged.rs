//! Paged KV cache: fixed-size pages from a shared slab pool.
//!
//! Long-lived softmax/quadratic/blockdiag decode sessions each grow a
//! `KvCache` linearly with generated tokens; with many concurrent
//! sessions that is an OOM, not a budget.  This module caps the total
//! KV footprint: every session's K/V rows live in fixed-size pages
//! drawn from one `PagePool` with a hard page budget.  When the pool is
//! full, the least-recently-stepped session loses a page (LRU across
//! sessions, never the session currently stepping); the owner
//! transparently recomputes the page from its token history on its next
//! step (recompute-on-miss), so eviction costs latency, not
//! correctness.  Gathered windows are bit-identical to an unpaged
//! `KvCache` because pages are copied back into one contiguous scratch
//! buffer before the (unchanged) decode kernels run.
//!
//! Pages store rows *encoded* at the pool's [`Precision`] — the same
//! per-row codec as the flat `KvCache`, so `[compute] precision`
//! shrinks paged sessions by the same factor, and (because per-row
//! quantization is a pure function of the row) a page lost to eviction
//! and refilled by deterministic recompute holds byte-identical
//! content to one that was never evicted.
//!
//! Memory: resident + recycled pages never exceed the budget, so
//! `bytes <= budget_pages * page_bytes`, where `page_bytes =
//! page_tokens * (d + dv) * kv_bytes + 2 * page_tokens *
//! quant_overhead` follows the precision (4/0 at f32, 2/0 at bf16 and
//! f16, 1/8 at int8-kv).

use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, Mutex};

use crate::faults::FaultPlan;
use crate::lowp::{decode_row, encode_row, Precision};

/// Pool-wide counters (eviction/recompute telemetry for ServeStats).
#[derive(Clone, Copy, Debug, Default)]
pub struct PageCounters {
    /// Pages evicted from idle sessions to satisfy another allocation.
    pub evicted: u64,
    /// Pages refilled from token history after an eviction.
    pub recomputed: u64,
}

struct PoolInner {
    /// Resident pages, keyed by (session id, page index).  Pages hold
    /// *encoded* rows — see [`PagePool::slot_offsets`] for the layout.
    resident: HashMap<(u64, usize), Box<[u8]>>,
    /// Recycled page buffers awaiting reuse (resident + free <= budget).
    free: Vec<Box<[u8]>>,
    /// Last-step logical clock per session (LRU victim selection).
    touch: HashMap<u64, u64>,
    /// Sessions currently mid-step; never eviction victims.
    pinned: HashMap<u64, usize>,
    clock: u64,
    counters: PageCounters,
    /// Seeded fault schedule: when armed, fresh page acquisitions may
    /// be failed on schedule (chaos testing of the recompute/poison
    /// paths).  `None` in production.
    faults: Option<Arc<FaultPlan>>,
}

/// Shared slab allocator of fixed-size KV pages (clone freely; all
/// clones share the same budget and residency map).
pub struct PagePool {
    inner: Arc<Mutex<PoolInner>>,
    budget_pages: usize,
    page_tokens: usize,
    d: usize,
    dv: usize,
    prec: Precision,
}

impl Clone for PagePool {
    fn clone(&self) -> Self {
        Self {
            inner: Arc::clone(&self.inner),
            budget_pages: self.budget_pages,
            page_tokens: self.page_tokens,
            d: self.d,
            dv: self.dv,
            prec: self.prec,
        }
    }
}

/// Absolute byte ranges of one token slot within a page: K payload, V
/// payload, and the per-row quant-table entries (empty except at
/// int8-kv).
struct SlotOffsets {
    k: Range<usize>,
    v: Range<usize>,
    kq: Range<usize>,
    vq: Range<usize>,
}

/// Encode one row into its page slot.  The payload and quant regions
/// never overlap (every quant table lives after the last payload
/// slot), so one split yields both mutable views.
fn encode_slot(
    prec: Precision,
    page: &mut [u8],
    payload: &Range<usize>,
    quant: &Range<usize>,
    row: &[f32],
) {
    let (pay, qt) = page.split_at_mut(quant.start);
    encode_row(prec, row, &mut pay[payload.clone()], &mut qt[..quant.end - quant.start]);
}

impl PagePool {
    /// Poison-tolerant lock: a panic surfaced through `push`/`gather`
    /// (pool exhaustion mid-step) leaves the maps consistent, so later
    /// session drops must still be able to release their pages.
    fn lock(&self) -> std::sync::MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Full-width (f32) pool — the historical constructor.
    pub fn new(budget_pages: usize, page_tokens: usize, d: usize, dv: usize) -> Self {
        Self::with_precision(budget_pages, page_tokens, d, dv, Precision::F32)
    }

    /// Pool whose pages store rows encoded at `prec` (`[compute]
    /// precision` reaches here through the serving coordinator).
    pub fn with_precision(
        budget_pages: usize,
        page_tokens: usize,
        d: usize,
        dv: usize,
        prec: Precision,
    ) -> Self {
        assert!(budget_pages > 0, "page pool needs a nonzero budget");
        assert!(page_tokens > 0 && d > 0 && dv > 0);
        Self {
            inner: Arc::new(Mutex::new(PoolInner {
                resident: HashMap::new(),
                free: Vec::new(),
                touch: HashMap::new(),
                pinned: HashMap::new(),
                clock: 0,
                counters: PageCounters::default(),
                faults: None,
            })),
            budget_pages,
            page_tokens,
            d,
            dv,
            prec,
        }
    }

    /// Arm the pool with a fault-injection schedule (builder form so
    /// production call sites stay unchanged).
    pub fn with_faults(self, plan: Option<Arc<FaultPlan>>) -> Self {
        self.lock().faults = plan;
        self
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }
    pub fn budget_pages(&self) -> usize {
        self.budget_pages
    }
    /// Key-row width every cache on this pool must use.
    pub fn d(&self) -> usize {
        self.d
    }
    /// Value-row width every cache on this pool must use.
    pub fn dv(&self) -> usize {
        self.dv
    }
    /// Storage precision of every page in this pool.
    pub fn precision(&self) -> Precision {
        self.prec
    }
    /// Bytes per page: `page_tokens` encoded K rows, then `page_tokens`
    /// encoded V rows, then the K and V quant tables (int8-kv only).
    pub fn page_bytes(&self) -> usize {
        let pt = self.page_tokens;
        pt * (self.d + self.dv) * self.prec.kv_bytes() + 2 * pt * self.prec.row_overhead_bytes()
    }

    /// Byte layout of one token slot within a page.
    fn slot_offsets(&self, slot: usize) -> SlotOffsets {
        let (kb, ov, pt) = (self.prec.kv_bytes(), self.prec.row_overhead_bytes(), self.page_tokens);
        let vbase = pt * self.d * kb;
        let kqbase = pt * (self.d + self.dv) * kb;
        let vqbase = kqbase + pt * ov;
        SlotOffsets {
            k: slot * self.d * kb..(slot + 1) * self.d * kb,
            v: vbase + slot * self.dv * kb..vbase + (slot + 1) * self.dv * kb,
            kq: kqbase + slot * ov..kqbase + (slot + 1) * ov,
            vq: vqbase + slot * ov..vqbase + (slot + 1) * ov,
        }
    }
    /// Hard ceiling on pool memory (resident + recycled buffers).
    pub fn budget_bytes(&self) -> usize {
        self.budget_pages * self.page_bytes()
    }

    pub fn resident_pages(&self) -> usize {
        self.lock().resident.len()
    }
    /// Bytes currently held by the pool (resident + free-list buffers);
    /// by construction never exceeds `budget_bytes()`.
    pub fn held_bytes(&self) -> usize {
        let inner = self.lock();
        (inner.resident.len() + inner.free.len()) * self.page_bytes()
    }
    pub fn counters(&self) -> PageCounters {
        self.lock().counters
    }

    /// Pin `sid` for the duration of a decode step: its pages cannot be
    /// evicted while the guard lives (the step's ensure/push/gather
    /// sequence spans several pool calls).
    pub fn pin(&self, sid: u64) -> PinGuard {
        self.lock().pinned.entry(sid).and_modify(|c| *c += 1).or_insert(1);
        PinGuard { pool: self.clone(), sid }
    }

    /// Advance the LRU clock for `sid` (call once per decode step).
    pub fn touch(&self, sid: u64) {
        let mut inner = self.lock();
        inner.clock += 1;
        let t = inner.clock;
        inner.touch.insert(sid, t);
    }

    /// Ensure a writable page exists for (sid, idx), evicting the
    /// oldest-idle unpinned session's lowest page if the budget is full.
    /// Returns true if the page was already resident.
    fn acquire(inner: &mut PoolInner, budget: usize, bytes: usize, sid: u64, idx: usize) -> Result<bool, String> {
        if inner.resident.contains_key(&(sid, idx)) {
            return Ok(true);
        }
        if inner.faults.as_ref().is_some_and(|p| p.on_page_alloc()) {
            return Err(format!("page pool allocation for session {sid} page {idx} failed (injected fault)"));
        }
        let buf = if let Some(buf) = inner.free.pop() {
            buf
        } else if inner.resident.len() < budget {
            vec![0u8; bytes].into_boxed_slice()
        } else {
            // Budget full: evict one page from the oldest-idle unpinned
            // session (never the allocating session, never a pinned one).
            let victim_sid = inner
                .resident
                .keys()
                .map(|&(s, _)| s)
                .filter(|&s| s != sid && !inner.pinned.contains_key(&s))
                .min_by_key(|&s| (inner.touch.get(&s).copied().unwrap_or(0), s));
            let Some(vs) = victim_sid else {
                return Err(format!(
                    "page pool exhausted: {} pages resident, all pinned or owned by session {sid} \
                     (raise [serve] page_pool_pages)",
                    inner.resident.len()
                ));
            };
            let victim_idx = inner
                .resident
                .keys()
                .filter(|&&(s, _)| s == vs)
                .map(|&(_, i)| i)
                .min()
                .expect("victim session owns at least one page");
            let buf = inner.resident.remove(&(vs, victim_idx)).unwrap();
            inner.counters.evicted += 1;
            buf
        };
        inner.resident.insert((sid, idx), buf);
        Ok(false)
    }

    fn unpin(&self, sid: u64) {
        let mut inner = self.lock();
        if let Some(c) = inner.pinned.get_mut(&sid) {
            *c -= 1;
            if *c == 0 {
                inner.pinned.remove(&sid);
            }
        }
    }

    /// Drop every page owned by `sid` (session close / retirement).
    pub fn release_session(&self, sid: u64) {
        let mut inner = self.lock();
        let keys: Vec<(u64, usize)> = inner.resident.keys().filter(|&&(s, _)| s == sid).copied().collect();
        for k in keys {
            let buf = inner.resident.remove(&k).unwrap();
            inner.free.push(buf);
        }
        inner.touch.remove(&sid);
    }
}

/// RAII un-pin for a stepping session.
pub struct PinGuard {
    pool: PagePool,
    sid: u64,
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.pool.unpin(self.sid);
    }
}

/// A session's view of the pool: same push/gather surface as `KvCache`,
/// but rows live in pool pages and may be evicted between steps.
pub struct PagedKvCache {
    pool: PagePool,
    sid: u64,
    d: usize,
    dv: usize,
    /// Total rows pushed (cache length).
    len: usize,
    /// Window start (blockdiag resets this; softmax/quadratic keep 0).
    base: usize,
    k_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
}

impl PagedKvCache {
    pub fn new(pool: &PagePool, sid: u64, d: usize, dv: usize) -> Self {
        assert_eq!(d, pool.d, "page pool was sized for d={}", pool.d);
        assert_eq!(dv, pool.dv, "page pool was sized for dv={}", pool.dv);
        Self {
            pool: pool.clone(),
            sid,
            d,
            dv,
            len: 0,
            base: 0,
            k_scratch: Vec::new(),
            v_scratch: Vec::new(),
        }
    }

    pub fn session_id(&self) -> u64 {
        self.sid
    }
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    pub fn window_len(&self) -> usize {
        self.len - self.base
    }
    pub fn d(&self) -> usize {
        self.d
    }
    pub fn dv(&self) -> usize {
        self.dv
    }
    /// Storage precision of the backing pool's pages.
    pub fn precision(&self) -> Precision {
        self.pool.prec
    }
    /// Bytes resident in the pool for this session right now.
    pub fn state_bytes(&self) -> usize {
        let inner = self.pool.lock();
        inner.resident.keys().filter(|&&(s, _)| s == self.sid).count() * self.pool.page_bytes()
    }

    /// Advance the pool LRU clock for this session (once per step).
    pub fn touch(&self) {
        self.pool.touch(self.sid);
    }

    /// Ensure every page covering the live window `[base, len)` is
    /// resident, refilling evicted pages row-by-row via `refill(pos,
    /// k_row, v_row)` (deterministic recompute from token history).
    /// Returns the number of pages recomputed.
    pub fn ensure_resident(
        &mut self,
        mut refill: impl FnMut(usize, &mut [f32], &mut [f32]) -> Result<(), String>,
    ) -> Result<usize, String> {
        if self.len == self.base {
            return Ok(0);
        }
        let pt = self.pool.page_tokens;
        let bytes = self.pool.page_bytes();
        let budget = self.pool.budget_pages;
        let prec = self.pool.prec;
        let (first, last) = (self.base / pt, (self.len - 1) / pt);
        let mut inner = self.pool.lock();
        let mut recomputed = 0usize;
        // Recomputed rows land in f32 scratch and are re-encoded with
        // the same pure per-row codec `push` used, so a refilled page
        // is byte-identical to one that was never evicted.
        let mut krow = vec![0.0f32; self.d];
        let mut vrow = vec![0.0f32; self.dv];
        for idx in first..=last {
            if PagePool::acquire(&mut inner, budget, bytes, self.sid, idx)? {
                continue; // already resident
            }
            // Freshly (re)acquired: refill the live rows of this page.
            let lo = (idx * pt).max(self.base);
            let hi = ((idx + 1) * pt).min(self.len);
            for pos in lo..hi {
                let slot = pos % pt;
                refill(pos, &mut krow, &mut vrow)?;
                let off = self.pool.slot_offsets(slot);
                let page = inner.resident.get_mut(&(self.sid, idx)).unwrap();
                encode_slot(prec, page, &off.k, &off.kq, &krow);
                encode_slot(prec, page, &off.v, &off.vq, &vrow);
            }
            recomputed += 1;
        }
        inner.counters.recomputed += recomputed as u64;
        Ok(recomputed)
    }

    /// Append one K/V row at position `len` (the page is acquired on
    /// demand; panics only if the pool budget cannot fit one page for a
    /// pinned session — surfaced by the coordinator as a request error).
    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d, "key row dim mismatch");
        assert_eq!(v.len(), self.dv, "value row dim mismatch");
        let pt = self.pool.page_tokens;
        let (idx, slot) = (self.len / pt, self.len % pt);
        let bytes = self.pool.page_bytes();
        let budget = self.pool.budget_pages;
        let prec = self.pool.prec;
        let off = self.pool.slot_offsets(slot);
        let mut inner = self.pool.lock();
        if let Err(e) = PagePool::acquire(&mut inner, budget, bytes, self.sid, idx) {
            panic!("{e}");
        }
        let page = inner.resident.get_mut(&(self.sid, idx)).unwrap();
        encode_slot(prec, page, &off.k, &off.kq, k);
        encode_slot(prec, page, &off.v, &off.vq, v);
        drop(inner);
        self.len += 1;
    }

    /// Start a fresh window (blockdiag block boundary): rows before
    /// `len` become dead, and fully-dead pages return to the free list.
    pub fn start_new_window(&mut self) {
        self.base = self.len;
        let pt = self.pool.page_tokens;
        let first_live = self.base / pt;
        let mut inner = self.pool.lock();
        let dead: Vec<(u64, usize)> = inner
            .resident
            .keys()
            .filter(|&&(s, i)| s == self.sid && i < first_live)
            .copied()
            .collect();
        for k in dead {
            let buf = inner.resident.remove(&k).unwrap();
            inner.free.push(buf);
        }
    }

    /// Copy the live window `[base, len)` into contiguous scratch and
    /// return `(keys, values)` — byte-identical to `KvCache::keys()` /
    /// `values()` for the same pushed rows.  Panics if a live page is
    /// not resident (the coordinator pins + ensures before stepping).
    pub fn gather(&mut self) -> (&[f32], &[f32]) {
        let rows = self.len - self.base;
        self.k_scratch.resize(rows * self.d, 0.0);
        self.v_scratch.resize(rows * self.dv, 0.0);
        let pt = self.pool.page_tokens;
        let prec = self.pool.prec;
        let inner = self.pool.lock();
        for (r, pos) in (self.base..self.len).enumerate() {
            let (idx, slot) = (pos / pt, pos % pt);
            let page = inner
                .resident
                .get(&(self.sid, idx))
                .unwrap_or_else(|| panic!("KV page ({}, {idx}) evicted mid-step (pin before gather)", self.sid));
            let off = self.pool.slot_offsets(slot);
            decode_row(
                prec,
                &page[off.k],
                &page[off.kq],
                &mut self.k_scratch[r * self.d..(r + 1) * self.d],
            );
            decode_row(
                prec,
                &page[off.v],
                &page[off.vq],
                &mut self.v_scratch[r * self.dv..(r + 1) * self.dv],
            );
        }
        drop(inner);
        (&self.k_scratch, &self.v_scratch)
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        self.pool.release_session(self.sid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(seed: f32, d: usize) -> Vec<f32> {
        (0..d).map(|i| seed + i as f32 * 0.25).collect()
    }

    #[test]
    fn paged_gather_matches_unpaged_cache() {
        let pool = PagePool::new(8, 3, 4, 4);
        let mut paged = PagedKvCache::new(&pool, 1, 4, 4);
        let mut flat_k = Vec::new();
        let mut flat_v = Vec::new();
        for t in 0..10 {
            let k = row(t as f32, 4);
            let v = row(100.0 + t as f32, 4);
            paged.push(&k, &v);
            flat_k.extend_from_slice(&k);
            flat_v.extend_from_slice(&v);
        }
        let (ks, vs) = paged.gather();
        assert_eq!(ks, &flat_k[..], "gathered keys must be bitwise identical");
        assert_eq!(vs, &flat_v[..], "gathered values must be bitwise identical");
    }

    #[test]
    fn lru_evicts_the_idle_session_and_recompute_restores_it() {
        // Budget of 2 pages, 2 tokens each: two sessions cannot both
        // keep a full 4-token history resident.
        let pool = PagePool::new(2, 2, 2, 2);
        let mut a = PagedKvCache::new(&pool, 1, 2, 2);
        let mut b = PagedKvCache::new(&pool, 2, 2, 2);
        a.touch();
        a.push(&[1.0, 2.0], &[3.0, 4.0]);
        a.push(&[5.0, 6.0], &[7.0, 8.0]); // a owns page 0 (full)
        b.touch();
        b.push(&[9.0, 9.5], &[9.6, 9.7]);
        b.push(&[9.8, 9.9], &[10.0, 10.1]); // pool full: a=1 page, b=1 page
        b.push(&[11.0, 11.5], &[11.6, 11.7]); // b needs page 1 -> evicts a's page
        assert_eq!(pool.counters().evicted, 1);
        assert_eq!(a.state_bytes(), 0, "idle session lost its page");
        assert!(pool.held_bytes() <= pool.budget_bytes());

        // a steps again: pin, recompute the lost page, gather bitwise.
        b.release_now_for_test();
        let _pin = pool.pin(1);
        a.touch();
        let rows = [([1.0f32, 2.0], [3.0f32, 4.0]), ([5.0, 6.0], [7.0, 8.0])];
        let n = a
            .ensure_resident(|pos, k, v| {
                k.copy_from_slice(&rows[pos].0);
                v.copy_from_slice(&rows[pos].1);
                Ok(())
            })
            .unwrap();
        assert_eq!(n, 1, "exactly the evicted page is recomputed");
        let (ks, _) = a.gather();
        assert_eq!(ks, &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(pool.counters().recomputed, 1);
    }

    impl PagedKvCache {
        fn release_now_for_test(&mut self) {
            self.pool.release_session(self.sid);
            self.len = 0;
            self.base = 0;
        }
    }

    #[test]
    fn low_precision_pools_shrink_page_bytes_and_bound_gather_error() {
        let f32p = PagePool::new(2, 8, 64, 64);
        assert_eq!(f32p.page_bytes(), 8 * 128 * 4);
        let bf = PagePool::with_precision(2, 8, 64, 64, Precision::Bf16);
        assert_eq!(bf.page_bytes() * 2, f32p.page_bytes());
        let q8 = PagePool::with_precision(2, 8, 64, 64, Precision::Int8Kv);
        // int8: 1-byte payload plus one (scale, zero) pair per K and V row.
        assert_eq!(q8.page_bytes(), 8 * 128 + 2 * 8 * 8);
        assert!(q8.page_bytes() * 2 <= f32p.page_bytes(), "int8-kv must halve page bytes");

        // A bf16 session round-trips its gather to bf16 tolerance.
        let pool = PagePool::with_precision(4, 3, 4, 4, Precision::Bf16);
        let mut c = PagedKvCache::new(&pool, 1, 4, 4);
        let mut flat_k = Vec::new();
        for t in 0..7 {
            let k = row(t as f32 * 0.3 - 0.9, 4);
            let v = row(2.0 - t as f32 * 0.5, 4);
            flat_k.extend_from_slice(&k);
            c.push(&k, &v);
        }
        let (ks, _) = c.gather();
        for (&x, &y) in flat_k.iter().zip(ks) {
            assert!((x - y).abs() <= x.abs().max(1.0) / 128.0, "bf16 gather drifted: {x} vs {y}");
        }
    }

    #[test]
    fn int8_recompute_after_eviction_is_byte_identical() {
        // The quantized-eviction contract: per-row quantization is a
        // pure function of the row, so a page lost to LRU eviction and
        // refilled by deterministic recompute must hand back exactly
        // the values a never-evicted page stores.
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..4)
            .map(|t| {
                let k: Vec<f32> = (0..4).map(|i| (t * 4 + i) as f32 * 0.37 - 1.1).collect();
                let v: Vec<f32> = (0..4).map(|i| (t * 4 + i) as f32 * -0.21 + 0.4).collect();
                (k, v)
            })
            .collect();
        // Reference: a roomy pool that never evicts.
        let calm = PagePool::with_precision(4, 2, 4, 4, Precision::Int8Kv);
        let mut undisturbed = PagedKvCache::new(&calm, 1, 4, 4);
        for (k, v) in &rows {
            undisturbed.push(k, v);
        }
        let (ks_ref, vs_ref) = {
            let (a, b) = undisturbed.gather();
            (a.to_vec(), b.to_vec())
        };
        assert_eq!(calm.counters().evicted, 0);

        // Churned pool: session 2's allocation steals session 1's page.
        let tight = PagePool::with_precision(2, 2, 4, 4, Precision::Int8Kv);
        let mut a = PagedKvCache::new(&tight, 1, 4, 4);
        a.touch();
        for (k, v) in &rows {
            a.push(k, v); // two pages: fills the budget
        }
        {
            let mut b = PagedKvCache::new(&tight, 2, 4, 4);
            b.touch();
            b.push(&rows[0].0, &rows[0].1); // evicts one of a's pages
            assert!(tight.counters().evicted >= 1);
        }
        let _pin = tight.pin(1);
        a.touch();
        let n = a
            .ensure_resident(|pos, k, v| {
                k.copy_from_slice(&rows[pos].0);
                v.copy_from_slice(&rows[pos].1);
                Ok(())
            })
            .unwrap();
        assert!(n >= 1, "the evicted page must be recomputed");
        let (ks, vs) = a.gather();
        assert_eq!(ks, &ks_ref[..], "recomputed K page drifted from the never-evicted bytes");
        assert_eq!(vs, &vs_ref[..], "recomputed V page drifted from the never-evicted bytes");
    }

    #[test]
    fn pool_never_exceeds_budget_under_churn() {
        let pool = PagePool::new(3, 2, 2, 2);
        let mut sessions: Vec<PagedKvCache> =
            (0..4).map(|s| PagedKvCache::new(&pool, s as u64, 2, 2)).collect();
        for t in 0..6 {
            for s in sessions.iter_mut() {
                s.touch();
                s.push(&[t as f32, 0.5], &[1.0, t as f32]);
                assert!(pool.held_bytes() <= pool.budget_bytes(), "budget is a hard ceiling");
            }
        }
        assert!(pool.counters().evicted > 0, "churn at 4 sessions x 6 tokens must evict");
        drop(sessions.pop());
        assert!(pool.held_bytes() <= pool.budget_bytes());
    }

    #[test]
    fn start_new_window_frees_dead_pages() {
        let pool = PagePool::new(8, 2, 2, 2);
        let mut c = PagedKvCache::new(&pool, 7, 2, 2);
        for t in 0..4 {
            c.push(&[t as f32, 0.0], &[0.0, t as f32]);
        }
        assert_eq!(pool.resident_pages(), 2);
        c.start_new_window();
        assert_eq!(c.window_len(), 0);
        assert_eq!(pool.resident_pages(), 0, "fully-dead pages return to the free list");
        c.push(&[9.0, 9.0], &[9.0, 9.0]);
        let (ks, _) = c.gather();
        assert_eq!(ks, &[9.0, 9.0], "window restarts cleanly mid-history");
    }

    #[test]
    fn injected_page_alloc_fault_fails_only_fresh_acquisitions() {
        use crate::config::FaultsConfig;
        // Fail the 2nd fresh acquisition: the first page allocates, the
        // second fails loudly, resident pages stay readable throughout.
        let plan = FaultPlan::from_config(&FaultsConfig {
            page_fail_start: 2,
            page_fail_every: 0,
            page_fail_limit: 1,
            ..Default::default()
        })
        .unwrap();
        let pool = PagePool::new(8, 2, 2, 2).with_faults(Some(plan.clone()));
        let mut c = PagedKvCache::new(&pool, 1, 2, 2);
        c.push(&[1.0, 1.0], &[1.0, 1.0]);
        c.push(&[2.0, 2.0], &[2.0, 2.0]); // same page: resident, no fault arrival
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.push(&[3.0, 3.0], &[3.0, 3.0]); // page 1: fresh acquisition -> injected failure
        }));
        assert!(r.is_err(), "the scheduled acquisition must fail");
        assert_eq!(plan.injected(), 1);
        // The fault point is spent: the retried acquisition succeeds
        // and the earlier rows were never corrupted.
        c.push(&[3.0, 3.0], &[3.0, 3.0]);
        let (ks, _) = c.gather();
        assert_eq!(&ks[..4], &[1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn pinned_sessions_are_never_victims() {
        let pool = PagePool::new(1, 2, 2, 2);
        let mut a = PagedKvCache::new(&pool, 1, 2, 2);
        let _pin = pool.pin(1);
        a.push(&[1.0, 1.0], &[1.0, 1.0]);
        let mut b = PagedKvCache::new(&pool, 2, 2, 2);
        // The only resident page belongs to pinned session 1: b's push
        // must fail loudly rather than corrupt a mid-step session.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            b.push(&[2.0, 2.0], &[2.0, 2.0]);
        }));
        assert!(r.is_err(), "allocation against an all-pinned pool must fail");
    }
}
