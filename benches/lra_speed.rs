//! Bench: paper Table 4 — per-step training time of each method on the
//! LRA-lite configuration (N=512), through the AOT train steps.

use lln::bench::Bench;
use lln::data::lra::{LraGen, LraTask};
use lln::runtime::{artifacts_available, artifacts_dir, Engine, HostTensor};
use lln::training::TrainDriver;

fn main() {
    let dir = artifacts_dir(None);
    if !artifacts_available(&dir) {
        println!("artifacts not built — run `make artifacts` first; skipping");
        return;
    }
    let mut engine = Engine::new(&dir).expect("engine");
    let mut b = Bench::new();
    b.time_budget_secs = 6.0;

    println!("== Table 4 bench: LRA-lite train step (B=4, N=512) ==");
    for method in ["softmax", "lln_diag", "performer", "nystrom"] {
        let artifact = format!("train_lra_{method}");
        let mut driver = TrainDriver::new(&engine, &dir, &artifact).expect("driver");
        let mut gen = LraGen::new(LraTask::Text, 512, 1);
        // warm (compile)
        let batch = gen.batch(4);
        driver
            .step(
                &mut engine,
                1e-3,
                &[
                    HostTensor::I32 { shape: vec![4, 512], data: batch.tokens },
                    HostTensor::I32 { shape: vec![4], data: batch.labels },
                ],
            )
            .expect("warm step");
        b.run(&format!("lra train step [{method}]"), 4.0 * 512.0, || {
            let batch = gen.batch(4);
            driver
                .step(
                    &mut engine,
                    1e-3,
                    &[
                        HostTensor::I32 { shape: vec![4, 512], data: batch.tokens },
                        HostTensor::I32 { shape: vec![4], data: batch.labels },
                    ],
                )
                .unwrap()
        });
    }
    println!("\npaper shape (Table 4): softmax slowest; LLN+Diag fastest accurate method.");
}
