//! Cross-layer integration tests: AOT artifacts x runtime x training x
//! coordinator.  All tests skip gracefully when `artifacts/` is absent
//! (`make test` builds artifacts first, so CI always exercises them).

use lln::attention;
use lln::data::{special, tasks::GlueGen, Corpus, GlueTask};
use lln::rng::Pcg64;
use lln::runtime::{artifacts_available, artifacts_dir, Engine, HostTensor};
use lln::tensor::Mat;
use lln::training::driver::{accuracy_from_logits, TrainDriver};

fn engine() -> Option<(Engine, std::path::PathBuf)> {
    let dir = artifacts_dir(None);
    if !artifacts_available(&dir) {
        eprintln!("skipping integration test: run `make artifacts`");
        return None;
    }
    Some((Engine::new(&dir).unwrap(), dir))
}

#[test]
fn every_micro_kernel_matches_native_reference() {
    let Some((mut eng, _dir)) = engine() else { return };
    let mut rng = Pcg64::seed(99);
    let (n, d) = (256usize, 64usize);
    let q = Mat::gaussian(n, d, 1.0, &mut rng);
    let k = Mat::gaussian(n, d, 1.0, &mut rng);
    let v = Mat::gaussian(n, d, 1.0, &mut rng);
    let t = |m: &Mat| HostTensor::from_mat(m);

    // (artifact, native) pairs — the full cross-layer correctness sweep.
    let lln_native = attention::lln_attention(&q, &k, &v, 2.0, 2.0);
    let cases: Vec<(&str, Mat, Vec<HostTensor>)> = vec![
        (
            "attn_softmax_n256",
            attention::softmax_attention(&q, &k, &v),
            vec![t(&q), t(&k), t(&v)],
        ),
        (
            "attn_lln_n256",
            lln_native.clone(),
            vec![t(&q), t(&k), t(&v), HostTensor::scalar_f32(2.0), HostTensor::scalar_f32(2.0)],
        ),
        (
            "attn_lln_diag_n256",
            attention::lln_diag_attention(&q, &k, &v, 2.0, 2.0, 64),
            vec![t(&q), t(&k), t(&v), HostTensor::scalar_f32(2.0), HostTensor::scalar_f32(2.0)],
        ),
        ("attn_elu_n256", attention::elu_attention(&q, &k, &v), vec![t(&q), t(&k), t(&v)]),
        (
            "attn_nystrom_n256",
            attention::nystrom_attention(&q, &k, &v, 32),
            vec![t(&q), t(&k), t(&v)],
        ),
    ];
    for (name, native, inputs) in cases {
        let out = eng.execute(name, &inputs).unwrap();
        let got = out[0].to_mat().unwrap();
        let err = got.max_abs_diff(&native);
        assert!(err < 5e-3, "{name}: PJRT vs native max|diff| = {err}");
    }
}

#[test]
fn linear_kernels_scale_to_16k_tokens() {
    let Some((mut eng, _dir)) = engine() else { return };
    let (n, d) = (16384usize, 64usize);
    let mut rng = Pcg64::seed(3);
    let mk = |rng: &mut Pcg64| HostTensor::F32 {
        shape: vec![n, d],
        data: (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
    };
    let inputs = vec![
        mk(&mut rng),
        mk(&mut rng),
        mk(&mut rng),
        HostTensor::scalar_f32(2.2),
        HostTensor::scalar_f32(2.2),
    ];
    let out = eng.execute("attn_lln_n16384", &inputs).unwrap();
    let data = out[0].as_f32().unwrap();
    assert_eq!(data.len(), n * d);
    assert!(data.iter().all(|x| x.is_finite()));
    // The softmax kernel at this length is intentionally absent (Table 2's OOM).
    assert!(eng.manifest().artifact("attn_softmax_n16384").is_err());
}

#[test]
fn glue_training_beats_chance_quickly() {
    let Some((mut eng, dir)) = engine() else { return };
    // SST2-like is the easiest task: ~80 steps separate it cleanly.
    let mut driver = TrainDriver::new(&eng, &dir, "train_glue_lln_diag").unwrap();
    let mut tg = GlueGen::new(GlueTask::Sst2, 512, 128, 5);
    for step in 0..140 {
        let b = tg.batch(16);
        let lr = if step < 8 { 2e-4 * (step + 1) as f64 } else { 1.5e-3 };
        let out = driver
            .step(
                &mut eng,
                lr,
                &[
                    HostTensor::I32 { shape: vec![16, 128], data: b.tokens },
                    HostTensor::I32 { shape: vec![16], data: b.labels },
                ],
            )
            .unwrap();
        if step % 35 == 0 {
            eprintln!("  step {step}: loss {:.4} gnorm {:.3}", out.loss, out.grad_norm);
        }
    }
    // Also measure on the *training* stream to separate train-path from
    // eval-path problems.
    let mut train_acc = 0.0;
    for _ in 0..4 {
        let b = tg.batch(16);
        let outs = driver
            .eval(&mut eng, &[HostTensor::I32 { shape: vec![16, 128], data: b.tokens.clone() }])
            .unwrap();
        let logits = outs[0].as_f32().unwrap();
        train_acc += accuracy_from_logits(logits, &b.labels, 4);
    }
    eprintln!("  train-dist acc: {:.3}", train_acc / 4.0);
    let mut eg = GlueGen::new(GlueTask::Sst2, 512, 128, 77);
    let mut acc_sum = 0.0;
    for _ in 0..8 {
        let b = eg.batch(16);
        let outs = driver
            .eval(&mut eng, &[HostTensor::I32 { shape: vec![16, 128], data: b.tokens }])
            .unwrap();
        acc_sum += accuracy_from_logits(outs[0].as_f32().unwrap(), &b.labels, 4);
    }
    let acc = acc_sum / 8.0;
    assert!(acc > 0.75, "LLN+Diag should learn SST2-like fast; got {acc}");
}

#[test]
fn mlm_eval_loss_decreases_on_held_out_data() {
    let Some((mut eng, dir)) = engine() else { return };
    let mut driver = TrainDriver::new(&eng, &dir, "train_tinymlm_softmax").unwrap();
    let mut corpus = Corpus::new(512, 11);
    let mut heldout = Corpus::new(512, 12);
    let eval_b = heldout.mlm_batch(4, 128, 0.15);
    let eval_data = [
        HostTensor::I32 { shape: vec![4, 128], data: eval_b.tokens.clone() },
        HostTensor::I32 { shape: vec![4, 128], data: eval_b.labels.clone() },
        HostTensor::F32 { shape: vec![4, 128], data: eval_b.weights.clone() },
    ];
    let loss_before = driver.eval(&mut eng, &eval_data).unwrap()[0].first_f32().unwrap();
    for _ in 0..15 {
        let b = corpus.mlm_batch(4, 128, 0.15);
        driver
            .step(
                &mut eng,
                3e-3,
                &[
                    HostTensor::I32 { shape: vec![4, 128], data: b.tokens },
                    HostTensor::I32 { shape: vec![4, 128], data: b.labels },
                    HostTensor::F32 { shape: vec![4, 128], data: b.weights },
                ],
            )
            .unwrap();
    }
    let loss_after = driver.eval(&mut eng, &eval_data).unwrap()[0].first_f32().unwrap();
    assert!(
        loss_after < loss_before - 0.2,
        "held-out loss should drop: {loss_before} -> {loss_after}"
    );
}

#[test]
fn checkpoint_restores_exact_eval_behaviour() {
    let Some((mut eng, dir)) = engine() else { return };
    let mut driver = TrainDriver::new(&eng, &dir, "train_tinymlm_elu").unwrap();
    let mut corpus = Corpus::new(512, 21);
    for _ in 0..3 {
        let b = corpus.mlm_batch(4, 128, 0.15);
        driver
            .step(
                &mut eng,
                1e-3,
                &[
                    HostTensor::I32 { shape: vec![4, 128], data: b.tokens },
                    HostTensor::I32 { shape: vec![4, 128], data: b.labels },
                    HostTensor::F32 { shape: vec![4, 128], data: b.weights },
                ],
            )
            .unwrap();
    }
    let eval_b = corpus.mlm_batch(4, 128, 0.15);
    let eval_data = [
        HostTensor::I32 { shape: vec![4, 128], data: eval_b.tokens },
        HostTensor::I32 { shape: vec![4, 128], data: eval_b.labels },
        HostTensor::F32 { shape: vec![4, 128], data: eval_b.weights },
    ];
    let loss1 = driver.eval(&mut eng, &eval_data).unwrap()[0].first_f32().unwrap();
    let ckpt = std::env::temp_dir().join("lln_integ_ckpt.bin");
    driver.save_checkpoint(&ckpt).unwrap();
    // Fresh driver + restore -> identical eval loss.
    let mut driver2 = TrainDriver::new(&eng, &dir, "train_tinymlm_elu").unwrap();
    driver2.params_mut().load_checkpoint(&ckpt).unwrap();
    let loss2 = driver2.eval(&mut eng, &eval_data).unwrap()[0].first_f32().unwrap();
    assert!((loss1 - loss2).abs() < 1e-5, "{loss1} vs {loss2}");
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn probe_artifact_feeds_analysis_instruments() {
    let Some((mut eng, dir)) = engine() else { return };
    let driver = TrainDriver::new(&eng, &dir, "train_mlm_softmax").unwrap();
    let mut corpus = Corpus::new(8192, 31);
    let tokens = corpus.mlm_batch(2, 128, 0.0).labels;
    let mut inputs = driver.params().to_literals().unwrap();
    inputs.push(
        HostTensor::I32 { shape: vec![2, 128], data: tokens }.to_literal().unwrap(),
    );
    let outs = eng.execute_literals("probe_softmax", &inputs).unwrap();
    let mats = outs[0].to_vec::<f32>().unwrap();
    let n = 128;
    // Each layer's matrix must be row-stochastic.
    for l in 0..4 {
        let m = Mat::from_vec(n, n, mats[l * n * n..(l + 1) * n * n].to_vec());
        assert!(m.is_stochastic(1e-3), "layer {l} not stochastic");
        let h = lln::stats::attention_entropy(&m);
        assert!(h > 0.0 && h <= (n as f64).log2() + 1e-6);
        let gap = lln::linalg::spectral_gap(&m, 300, 1e-8).gap;
        assert!((0.0..=1.0).contains(&gap));
    }
}

#[test]
fn serve_and_train_agree_on_params_schema() {
    let Some((eng, _dir)) = engine() else { return };
    // Every serve artifact's parameter inputs must match its model schema
    // in order and count — the worker relies on this blindly.
    for (name, spec) in &eng.manifest().artifacts {
        if !name.starts_with("serve_") {
            continue;
        }
        let model = eng.manifest().model(spec.meta.get("model").unwrap()).unwrap();
        let param_inputs: Vec<&str> = spec
            .inputs
            .iter()
            .filter(|i| i.is_param())
            .map(|i| i.name.as_str())
            .collect();
        let expected: Vec<String> =
            model.param_order.iter().map(|p| format!("p:{p}")).collect();
        assert_eq!(
            param_inputs,
            expected.iter().map(String::as_str).collect::<Vec<_>>(),
            "{name}"
        );
    }
}

#[test]
fn tokenizer_special_ids_consistent_with_generators() {
    // The serving path pads with PAD=0; generators must never emit
    // negative or out-of-range ids.
    let mut g = GlueGen::new(GlueTask::Nli, 512, 128, 3);
    for _ in 0..20 {
        let (t, _) = g.example();
        assert!(t.iter().all(|&x| x >= special::PAD && (x as usize) < 512));
    }
}
