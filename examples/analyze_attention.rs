//! Analysis tour (no artifacts needed): the paper's §3 instruments on
//! native attention — temperature, entropy, spectral gap, log-normal
//! fit, Fenton validation, moment matching.
//!
//!     cargo run --release --example analyze_attention

use lln::analysis::{self, fenton};
use lln::attention::{self, MomentMatcher, Method};
use lln::rng::Pcg64;
use lln::tensor::Mat;

fn main() {
    let (n, d) = (192usize, 64usize);
    let mut rng = Pcg64::seed(0);

    println!("== the softmax attention model (paper §3) ==");
    for sigma in [0.6f32, 1.0, 1.4] {
        let q = Mat::gaussian(n, d, sigma, &mut rng);
        let k = Mat::gaussian(n, d, sigma, &mut rng);
        let p = attention::softmax_attention_matrix(&q, &k);
        let tau = analysis::temperature(&q, &k);
        let h = lln::stats::attention_entropy(&p);
        let gap = lln::linalg::spectral_gap(&p, 400, 1e-8).gap;
        let s2 = lln::stats::log_variance(&p, 1e-30);
        println!(
            "sigma={sigma:.1}: temperature={tau:.3}  entropy={h:.2} bits  gap={gap:.3}  var(log P)={s2:.2} (theory {:.2})",
            (sigma as f64).powi(4)
        );
    }

    println!("\n== Fenton's approximation (Prop 4.1 machinery) ==");
    for p in fenton::moderate_sweep(d, 3000, 1) {
        println!(
            "s2={:.1}: Fenton predicts {:.4}, measured {:.4}",
            p.s2, p.fenton_theory, p.measured
        );
    }

    println!("\n== moment matching (paper App A.7) ==");
    let mm = MomentMatcher::from_artifacts(std::path::Path::new("artifacts"))
        .unwrap_or_else(|| MomentMatcher::fit(192, 64, &[0, 1]));
    println!("fitted broad-regime constants: a={:.4} b={:.4}", mm.a, mm.b);
    for s in [0.9f64, 1.2, 1.5] {
        let (alpha, beta) = mm.alpha_beta(s, s);
        println!("sigma={s}: alpha=beta={alpha:.2} (paper fig 9 range: ~2-2.2 at sigma~1)");
        let _ = beta;
    }

    println!("\n== concentration across kernels (fig 2 condensed) ==");
    let sigmas = [0.5f64, 1.0, 1.5];
    for (label, method, matched) in [
        ("softmax", Method::Softmax, false),
        ("lln+mm", Method::Lln, true),
        ("relu", Method::Relu, false),
    ] {
        let pts = analysis::concentration_profile(
            method,
            &sigmas,
            128,
            64,
            matched.then_some(&mm),
            7,
        );
        let hs: Vec<String> = pts.iter().map(|p| format!("{:.2}", p.entropy)).collect();
        println!("{label:>8}: entropy over sigma {sigmas:?} = {}", hs.join(", "));
    }
    println!("\nanalysis OK — see `lln exp fig2|fig5|fig6|fig7` for the full figures");
}
