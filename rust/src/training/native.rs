//! Native training loop: backprop through the native attention
//! backends, no AOT artifacts anywhere (ROADMAP: "native training
//! loop").
//!
//! Three pieces:
//!
//! * [`Tape`] — a minimal reverse-mode autograd tape over [`Mat`] ops:
//!   each op records its parents and a backward closure (capturing the
//!   saved activations it needs), and [`Tape::backward`] walks the
//!   nodes in reverse creation order accumulating cotangents.  The op
//!   set is exactly what the MLM model needs: embedding lookup,
//!   matmul, bias, ReLU, layernorm, attention (through
//!   [`AttentionBackend::forward_train`] /
//!   [`AttentionBackend::backward`] — the fused recompute kernels, so
//!   the O(n·tile) memory story survives the backward), and the
//!   weighted MLM cross-entropy.
//!
//! * [`TrainStep`] — one optimizer step behind a uniform interface,
//!   with two implementations: [`ArtifactStep`] (today's AOT
//!   [`TrainDriver`] path) and [`NativeStep`] (a RoBERTa-lite MLM
//!   encoder trained natively with the tape + [`Adam`]).  The fig. 8 /
//!   fig. 1 harnesses pick [`NativeStep`] automatically when no
//!   artifacts directory exists (`lln train --native` forces it).
//!
//! * [`NativeStep`] emits the same [`StepTelemetry`] the AOT driver
//!   does — loss, grad-norm, per-layer `[alpha, beta, sigma_q,
//!   sigma_k]` — and, for LLN, *learns* alpha/beta through the
//!   `dα`/`dβ` hooks of the backward kernels (the paper's fig. 9
//!   trajectories, without baked moment-matching constants).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::attention::{backend_for, AttentionBackend, AttnSpec, BackendParams, Method};
use crate::data::MlmBatch;
use crate::rng::Pcg64;
use crate::runtime::{Engine, HostTensor};
use crate::tensor::{vec_ops, Mat};
use crate::training::driver::{StepTelemetry, TrainDriver};

// ---------------------------------------------------------------------------
// Tape
// ---------------------------------------------------------------------------

/// Backward closure of one tape node: output cotangent in, one
/// gradient per parent out (same order as the recorded parents).
type BackFn = Box<dyn Fn(&Mat) -> Vec<Mat>>;

/// Minimal reverse-mode autograd tape over [`Mat`] ops.  Node ids are
/// creation-ordered, so parents always precede children and one
/// reverse walk is a valid topological order.  Leaves keep their
/// accumulated gradients; intermediate cotangents are dropped as soon
/// as they are consumed.
///
/// Ops clone the operand matrices they need into their backward
/// closures (rather than re-reading `vals` by parent id at backward
/// time) — a deliberate simplicity-over-memory trade: the closures
/// stay self-contained `Fn(&Mat) -> Vec<Mat>` values, at the cost of
/// roughly doubling the held activation memory for the life of one
/// step.  At the shapes this trainer serves (tiny/small MLM models,
/// low-MB activations) that is noise; revisit if the native trainer
/// ever grows to models where activation memory dominates.
#[derive(Default)]
pub struct Tape {
    vals: Vec<Mat>,
    parents: Vec<Vec<usize>>,
    backs: Vec<Option<BackFn>>,
}

impl Tape {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of recorded nodes.
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// A leaf node (parameter or constant input).
    pub fn leaf(&mut self, v: Mat) -> usize {
        self.vals.push(v);
        self.parents.push(Vec::new());
        self.backs.push(None);
        self.vals.len() - 1
    }

    fn push(&mut self, v: Mat, parents: Vec<usize>, back: BackFn) -> usize {
        self.vals.push(v);
        self.parents.push(parents);
        self.backs.push(Some(back));
        self.vals.len() - 1
    }

    /// Forward value of a node.
    pub fn val(&self, id: usize) -> &Mat {
        &self.vals[id]
    }

    /// `a @ b`.
    pub fn matmul(&mut self, a: usize, b: usize) -> usize {
        let av = self.vals[a].clone();
        let bv = self.vals[b].clone();
        let out = av.matmul(&bv);
        self.push(
            out,
            vec![a, b],
            Box::new(move |d| vec![d.matmul_t(&bv), av.transpose().matmul(d)]),
        )
    }

    /// Elementwise `a + b` (same shape).
    pub fn add(&mut self, a: usize, b: usize) -> usize {
        let out = self.vals[a].add(&self.vals[b]);
        self.push(out, vec![a, b], Box::new(|d: &Mat| vec![d.clone(), d.clone()]))
    }

    /// Add a `1×n` bias row to every row of `x`.
    pub fn add_bias(&mut self, x: usize, b: usize) -> usize {
        let bv = self.vals[b].clone();
        assert_eq!(bv.rows(), 1, "bias must be a row vector");
        assert_eq!(bv.cols(), self.vals[x].cols(), "bias width mismatch");
        let mut out = self.vals[x].clone();
        for r in 0..out.rows() {
            for (o, &bb) in out.row_mut(r).iter_mut().zip(bv.row(0)) {
                *o += bb;
            }
        }
        let cols = bv.cols();
        self.push(
            out,
            vec![x, b],
            Box::new(move |d| {
                let mut db = Mat::zeros(1, cols);
                for r in 0..d.rows() {
                    for (o, &g) in db.data_mut().iter_mut().zip(d.row(r)) {
                        *o += g;
                    }
                }
                vec![d.clone(), db]
            }),
        )
    }

    /// Elementwise ReLU.
    pub fn relu(&mut self, x: usize) -> usize {
        let xv = self.vals[x].clone();
        let out = xv.map(|v| v.max(0.0));
        self.push(
            out,
            vec![x],
            Box::new(move |d| {
                let mut dx = d.clone();
                for (o, &v) in dx.data_mut().iter_mut().zip(xv.data()) {
                    if v <= 0.0 {
                        *o = 0.0;
                    }
                }
                vec![dx]
            }),
        )
    }

    /// Row-wise layer normalization with learned `1×n` gain/shift.
    pub fn layernorm(&mut self, x: usize, gamma: usize, beta: usize) -> usize {
        const LN_EPS: f32 = 1e-5;
        let xv = self.vals[x].clone();
        let gv = self.vals[gamma].clone();
        let bv = self.vals[beta].clone();
        let (rows, cols) = xv.shape();
        assert_eq!(gv.shape(), (1, cols), "layernorm gain shape");
        assert_eq!(bv.shape(), (1, cols), "layernorm shift shape");
        let mut out = Mat::zeros(rows, cols);
        let mut xhat = Mat::zeros(rows, cols);
        let mut inv_std = vec![0.0f32; rows];
        for r in 0..rows {
            let row = xv.row(r);
            let mu = row.iter().sum::<f32>() / cols as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
            let istd = 1.0 / (var + LN_EPS).sqrt();
            inv_std[r] = istd;
            let xh = xhat.row_mut(r);
            let orow = out.row_mut(r);
            for j in 0..cols {
                let h = (row[j] - mu) * istd;
                xh[j] = h;
                orow[j] = h * gv.get(0, j) + bv.get(0, j);
            }
        }
        self.push(
            out,
            vec![x, gamma, beta],
            Box::new(move |d| {
                let mut dx = Mat::zeros(rows, cols);
                let mut dg = Mat::zeros(1, cols);
                let mut db = Mat::zeros(1, cols);
                for r in 0..rows {
                    let dorow = d.row(r);
                    let xh = xhat.row(r);
                    {
                        let dgrow = dg.data_mut();
                        for j in 0..cols {
                            dgrow[j] += dorow[j] * xh[j];
                        }
                    }
                    {
                        let dbrow = db.data_mut();
                        for j in 0..cols {
                            dbrow[j] += dorow[j];
                        }
                    }
                    // dx̂ = d ∘ γ;  dx = (dx̂ − mean(dx̂) − x̂·mean(dx̂∘x̂))/σ
                    let mut mean_dxh = 0.0f32;
                    let mut mean_dxh_xh = 0.0f32;
                    for j in 0..cols {
                        let dxh = dorow[j] * gv.get(0, j);
                        mean_dxh += dxh;
                        mean_dxh_xh += dxh * xh[j];
                    }
                    mean_dxh /= cols as f32;
                    mean_dxh_xh /= cols as f32;
                    let istd = inv_std[r];
                    let dxrow = dx.row_mut(r);
                    for j in 0..cols {
                        let dxh = dorow[j] * gv.get(0, j);
                        dxrow[j] = (dxh - mean_dxh - xh[j] * mean_dxh_xh) * istd;
                    }
                }
                vec![dx, dg, db]
            }),
        )
    }

    /// Embedding lookup: row `r` of the output is
    /// `table[tokens[r]] + pos[r % n]` — token + learned positional
    /// embedding for `tokens.len() / n` packed sequences of length
    /// `n`.  Backward scatter-adds into both tables.
    pub fn embed(&mut self, table: usize, pos: usize, tokens: &[i32], n: usize) -> usize {
        let tv = self.vals[table].clone();
        let pv = self.vals[pos].clone();
        let d = tv.cols();
        assert_eq!(pv.cols(), d, "token/positional embedding width mismatch");
        assert!(n >= 1 && tokens.len() % n == 0, "token count must pack whole sequences");
        let rows = tokens.len();
        let vrows = tv.rows();
        let prows = pv.rows();
        let toks: Vec<usize> =
            tokens.iter().map(|&t| (t.max(0) as usize).min(vrows.saturating_sub(1))).collect();
        let mut out = Mat::zeros(rows, d);
        for (r, &t) in toks.iter().enumerate() {
            let prow = (r % n) % prows.max(1);
            for ((o, &a), &b) in out.row_mut(r).iter_mut().zip(tv.row(t)).zip(pv.row(prow)) {
                *o = a + b;
            }
        }
        self.push(
            out,
            vec![table, pos],
            Box::new(move |dout| {
                let mut dt = Mat::zeros(vrows, d);
                let mut dp = Mat::zeros(prows, d);
                for (r, &t) in toks.iter().enumerate() {
                    let dorow = dout.row(r);
                    for (o, &g) in dt.row_mut(t).iter_mut().zip(dorow) {
                        *o += g;
                    }
                    let prow = (r % n) % prows.max(1);
                    for (o, &g) in dp.row_mut(prow).iter_mut().zip(dorow) {
                        *o += g;
                    }
                }
                vec![dt, dp]
            }),
        )
    }

    /// Attention over `seqs` packed sequences (rows split evenly),
    /// routed through the backend's fused
    /// [`forward_train`](AttentionBackend::forward_train) /
    /// [`backward`](AttentionBackend::backward) — `alpha` / `beta` are
    /// `1×1` tape nodes so LLN's exponents receive gradients.  `Err`
    /// when the method has no native backward.
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        &mut self,
        q: usize,
        k: usize,
        v: usize,
        alpha: usize,
        beta: usize,
        method: Method,
        base: BackendParams,
        seqs: usize,
    ) -> Result<usize, String> {
        let qv = self.vals[q].clone();
        let kv = self.vals[k].clone();
        let vv = self.vals[v].clone();
        let rows = qv.rows();
        assert!(seqs >= 1 && rows % seqs == 0, "rows must pack whole sequences");
        let n = rows / seqs;
        let a_val = self.vals[alpha].get(0, 0);
        let b_val = self.vals[beta].get(0, 0);
        let backend: Arc<dyn AttentionBackend> =
            Arc::from(backend_for(method, BackendParams { alpha: a_val, beta: b_val, ..base }));
        let spec = AttnSpec::FULL;
        let d = qv.cols();
        let dvc = vv.cols();
        let mut out = Mat::zeros(rows, dvc);
        let mut caches = Vec::with_capacity(seqs);
        for s in 0..seqs {
            let qb = slice_rows(&qv, s * n, n);
            let kb = slice_rows(&kv, s * n, n);
            let vb = slice_rows(&vv, s * n, n);
            let (ob, cache) = backend.forward_train(&qb, &kb, &vb, &spec)?;
            out.data_mut()[s * n * dvc..(s + 1) * n * dvc].copy_from_slice(ob.data());
            caches.push(cache);
        }
        Ok(self.push(
            out,
            vec![q, k, v, alpha, beta],
            Box::new(move |dout| {
                let mut dq = Mat::zeros(rows, d);
                let mut dk = Mat::zeros(rows, d);
                let mut dvm = Mat::zeros(rows, dvc);
                let mut da = 0.0f32;
                let mut db = 0.0f32;
                for s in 0..seqs {
                    let qb = slice_rows(&qv, s * n, n);
                    let kb = slice_rows(&kv, s * n, n);
                    let vb = slice_rows(&vv, s * n, n);
                    let dob = slice_rows(dout, s * n, n);
                    let g = backend
                        .backward(&qb, &kb, &vb, &spec, &caches[s], &dob)
                        .expect("native attention backward (forward_train succeeded)");
                    dq.data_mut()[s * n * d..(s + 1) * n * d].copy_from_slice(g.dq.data());
                    dk.data_mut()[s * n * d..(s + 1) * n * d].copy_from_slice(g.dk.data());
                    dvm.data_mut()[s * n * dvc..(s + 1) * n * dvc].copy_from_slice(g.dv.data());
                    da += g.dalpha;
                    db += g.dbeta;
                }
                vec![
                    dq,
                    dk,
                    dvm,
                    Mat::from_vec(1, 1, vec![da]),
                    Mat::from_vec(1, 1, vec![db]),
                ]
            }),
        ))
    }

    /// Weighted MLM cross-entropy over row logits: a `1×1` loss node,
    /// `loss = Σ_r w_r · (−log softmax(logits_r)[label_r]) / Σ_r w_r`
    /// (f64 accumulation).
    pub fn mlm_loss(&mut self, logits: usize, labels: &[i32], weights: &[f32]) -> usize {
        let lv = &self.vals[logits];
        let (rows, classes) = lv.shape();
        assert_eq!(labels.len(), rows, "label count mismatch");
        assert_eq!(weights.len(), rows, "weight count mismatch");
        assert!(classes >= 1, "no classes");
        let mut probs = lv.clone();
        probs.softmax_rows();
        let wsum = weights.iter().map(|&w| w as f64).sum::<f64>().max(1e-12);
        let labs: Vec<usize> =
            labels.iter().map(|&l| (l.max(0) as usize).min(classes - 1)).collect();
        let mut loss = 0.0f64;
        for (r, &lab) in labs.iter().enumerate() {
            let w = weights[r] as f64;
            if w == 0.0 {
                continue;
            }
            loss -= w * (probs.get(r, lab).max(1e-12) as f64).ln();
        }
        loss /= wsum;
        let out = Mat::from_vec(1, 1, vec![loss as f32]);
        let w: Vec<f32> = weights.to_vec();
        self.push(
            out,
            vec![logits],
            Box::new(move |dout| {
                let g = dout.get(0, 0);
                let mut dl = probs.clone();
                for (r, &lab) in labs.iter().enumerate() {
                    let row = dl.row_mut(r);
                    if w[r] == 0.0 {
                        row.fill(0.0);
                        continue;
                    }
                    row[lab] -= 1.0;
                    let scale = g * w[r] / wsum as f32;
                    for x in row.iter_mut() {
                        *x *= scale;
                    }
                }
                vec![dl]
            }),
        )
    }

    /// Reverse-mode sweep from `root` (typically the `1×1` loss).
    /// Returns one gradient slot per node; leaf slots keep their
    /// accumulated gradients, interior slots are drained as they are
    /// consumed (`None`).  Nodes the root does not depend on stay
    /// `None`.
    pub fn backward(&self, root: usize) -> Vec<Option<Mat>> {
        let mut grads: Vec<Option<Mat>> = (0..self.vals.len()).map(|_| None).collect();
        let (r, c) = self.vals[root].shape();
        grads[root] = Some(Mat::from_vec(r, c, vec![1.0; r * c]));
        for id in (0..=root).rev() {
            let Some(back) = self.backs[id].as_ref() else { continue };
            let Some(g) = grads[id].take() else { continue };
            let pgrads = back(&g);
            debug_assert_eq!(pgrads.len(), self.parents[id].len());
            for (&p, pg) in self.parents[id].iter().zip(pgrads) {
                match grads[p].as_mut() {
                    Some(acc) => {
                        for (a, &x) in acc.data_mut().iter_mut().zip(pg.data()) {
                            *a += x;
                        }
                    }
                    None => grads[p] = Some(pg),
                }
            }
        }
        grads
    }
}

/// Copy `len` contiguous rows of `m` starting at `start` into an owned
/// [`Mat`] (the per-sequence view the attention op hands the backend).
fn slice_rows(m: &Mat, start: usize, len: usize) -> Mat {
    let c = m.cols();
    Mat::from_vec(len, c, m.data()[start * c..(start + len) * c].to_vec())
}

// ---------------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------------

/// Standard Adam with f64 bias correction, one moment pair per
/// parameter tensor — the native counterpart of the optimizer baked
/// into the AOT train step.
pub struct Adam {
    m: Vec<Mat>,
    v: Vec<Mat>,
    t: usize,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Adam {
    pub fn new(params: &[Mat]) -> Self {
        let zeros = |p: &Mat| Mat::zeros(p.rows(), p.cols());
        Self {
            m: params.iter().map(zeros).collect(),
            v: params.iter().map(zeros).collect(),
            t: 0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    pub fn step_count(&self) -> usize {
        self.t
    }

    pub fn step(&mut self, params: &mut [Mat], grads: &[Mat], lr: f64) {
        assert_eq!(params.len(), grads.len(), "param/grad arity mismatch");
        self.t += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for ((pv, &gv), (mv, vv)) in p
                .data_mut()
                .iter_mut()
                .zip(g.data())
                .zip(m.data_mut().iter_mut().zip(v.data_mut().iter_mut()))
            {
                let g64 = gv as f64;
                let m64 = b1 * (*mv as f64) + (1.0 - b1) * g64;
                let v64 = b2 * (*vv as f64) + (1.0 - b2) * g64 * g64;
                *mv = m64 as f32;
                *vv = v64 as f32;
                *pv -= (lr * (m64 / bc1) / ((v64 / bc2).sqrt() + self.eps)) as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TrainStep: one optimizer step behind a uniform interface
// ---------------------------------------------------------------------------

/// One MLM optimizer step — the seam between the fig. 8 / fig. 1
/// harnesses and *how* the step executes (AOT artifact vs native
/// backprop).  Both implementations speak [`StepTelemetry`].
pub trait TrainStep {
    /// Human-readable backend tag (`artifact:…` / `native:…`).
    fn name(&self) -> String;
    /// `(batch, seqlen)` the step consumes.
    fn batch_shape(&self) -> (usize, usize);
    /// Vocabulary size the corpus should generate.
    fn vocab(&self) -> usize;
    /// One optimizer step on an MLM batch.
    fn step(&mut self, lr: f64, batch: &MlmBatch) -> Result<StepTelemetry>;
    /// Forward-only loss on a held-out batch.
    fn eval_loss(&mut self, batch: &MlmBatch) -> Result<f32>;
}

/// [`TrainStep`] over today's AOT path: a PJRT [`Engine`] plus the
/// [`TrainDriver`] that steps a `train_*` executable.
pub struct ArtifactStep {
    engine: Engine,
    driver: TrainDriver,
    batch: usize,
    seqlen: usize,
    vocab: usize,
}

impl ArtifactStep {
    pub fn new(dir: &Path, artifact: &str) -> Result<Self> {
        let engine = Engine::new(dir)?;
        let spec = engine.manifest().artifact(artifact)?.clone();
        let batch = spec.meta_usize("batch").unwrap_or(8);
        let seqlen = spec.meta_usize("seqlen").unwrap_or(128);
        let model_tag = spec.meta.get("model").cloned().unwrap_or_default();
        let vocab = engine
            .manifest()
            .model(&model_tag)?
            .config
            .get("vocab_size")
            .and_then(|s| s.parse().ok())
            .unwrap_or(8192);
        let driver = TrainDriver::new(&engine, dir, artifact)?;
        Ok(Self { engine, driver, batch, seqlen, vocab })
    }

    fn data_tensors(&self, batch: &MlmBatch) -> [HostTensor; 3] {
        let (b, n) = (self.batch, self.seqlen);
        [
            HostTensor::I32 { shape: vec![b, n], data: batch.tokens.clone() },
            HostTensor::I32 { shape: vec![b, n], data: batch.labels.clone() },
            HostTensor::F32 { shape: vec![b, n], data: batch.weights.clone() },
        ]
    }
}

impl TrainStep for ArtifactStep {
    fn name(&self) -> String {
        format!("artifact:{}", self.driver.artifact)
    }
    fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seqlen)
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn step(&mut self, lr: f64, batch: &MlmBatch) -> Result<StepTelemetry> {
        let data = self.data_tensors(batch);
        self.driver.step(&mut self.engine, lr, &data)
    }
    fn eval_loss(&mut self, batch: &MlmBatch) -> Result<f32> {
        let data = self.data_tensors(batch);
        let outs = self.driver.eval(&mut self.engine, &data)?;
        outs[0].first_f32()
    }
}

// ---------------------------------------------------------------------------
// NativeStep: the RoBERTa-lite MLM encoder trained natively
// ---------------------------------------------------------------------------

/// Model + batch dimensions of the native MLM trainer.
#[derive(Clone, Copy, Debug)]
pub struct NativeShape {
    pub batch: usize,
    pub seqlen: usize,
    pub d_model: usize,
    pub layers: usize,
    pub ff: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl NativeShape {
    /// Dimensions matching the AOT size tags: `"mlm"` is the small
    /// fig. 8 model shape, anything else the tiny CI/test shape.
    pub fn for_size(size: &str) -> Self {
        if size == "mlm" {
            Self { batch: 8, seqlen: 128, d_model: 64, layers: 4, ff: 128, vocab: 8192, seed: 0 }
        } else {
            Self { batch: 4, seqlen: 64, d_model: 32, layers: 2, ff: 64, vocab: 1024, seed: 0 }
        }
    }
}

/// Per-layer parameter indices into [`NativeStep::params`].
struct LayerIdx {
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln1_g: usize,
    ln1_b: usize,
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    ln2_g: usize,
    ln2_b: usize,
    alpha: usize,
    beta: usize,
}

/// Parameter indices of the whole model.
struct ParamIdx {
    tok: usize,
    pos: usize,
    layers: Vec<LayerIdx>,
    wout: usize,
    bout: usize,
}

/// Node handles a forward pass exposes to telemetry/probing.
struct ForwardRefs {
    loss: usize,
    /// Per layer: the (q, k) projection nodes.
    layer_qk: Vec<(usize, usize)>,
}

/// [`TrainStep`] over the native backends: a single-head RoBERTa-lite
/// MLM encoder (embed + per-layer [attention → residual → layernorm →
/// ReLU MLP → residual → layernorm] + vocab head) whose attention runs
/// through [`AttentionBackend::forward_train`] / `backward` — the
/// fused recompute kernels — and whose LLN alpha/beta are *learned*
/// parameters.
pub struct NativeStep {
    method: Method,
    shape: NativeShape,
    base: BackendParams,
    params: Vec<Mat>,
    idx: ParamIdx,
    adam: Adam,
    steps_done: usize,
}

impl NativeStep {
    /// Build a fresh model.  `Err` for methods without a native
    /// backward (Nystrom/Linformer and the composite/projection
    /// methods) — train those through artifacts instead.
    pub fn new(method: Method, shape: NativeShape) -> Result<Self> {
        if !matches!(
            method,
            Method::Softmax | Method::Lln | Method::Elu | Method::Relu | Method::Quadratic
        ) {
            bail!(
                "{} attention has no native backward pass; train it through AOT artifacts, or \
                 pick one of softmax/lln/elu/relu/quadratic",
                method.name()
            );
        }
        assert!(shape.batch >= 1 && shape.seqlen >= 1 && shape.layers >= 1);
        assert!(shape.vocab > crate::data::special::FIRST_CONTENT as usize);
        let mut rng = Pcg64::new(shape.seed, 0x7A1e);
        let (d, f, v) = (shape.d_model, shape.ff, shape.vocab);
        let std = 0.02f32;
        let mut params: Vec<Mat> = Vec::new();
        let push = |params: &mut Vec<Mat>, m: Mat| -> usize {
            params.push(m);
            params.len() - 1
        };
        let tok = push(&mut params, Mat::gaussian(v, d, std, &mut rng));
        let pos = push(&mut params, Mat::gaussian(shape.seqlen, d, std, &mut rng));
        let mut layers = Vec::with_capacity(shape.layers);
        // LLN starts near the paper's trained equilibrium (fig. 9);
        // the exponents are then learned via dα/dβ.
        let alpha0 = if method == Method::Lln { 2.0 } else { 1.0 };
        for _ in 0..shape.layers {
            layers.push(LayerIdx {
                wq: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                wk: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                wv: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                wo: push(&mut params, Mat::gaussian(d, d, std, &mut rng)),
                ln1_g: push(&mut params, Mat::from_vec(1, d, vec![1.0; d])),
                ln1_b: push(&mut params, Mat::zeros(1, d)),
                w1: push(&mut params, Mat::gaussian(d, f, std, &mut rng)),
                b1: push(&mut params, Mat::zeros(1, f)),
                w2: push(&mut params, Mat::gaussian(f, d, std, &mut rng)),
                b2: push(&mut params, Mat::zeros(1, d)),
                ln2_g: push(&mut params, Mat::from_vec(1, d, vec![1.0; d])),
                ln2_b: push(&mut params, Mat::zeros(1, d)),
                alpha: push(&mut params, Mat::from_vec(1, 1, vec![alpha0])),
                beta: push(&mut params, Mat::from_vec(1, 1, vec![alpha0])),
            });
        }
        let wout = push(&mut params, Mat::gaussian(d, v, std, &mut rng));
        let bout = push(&mut params, Mat::zeros(1, v));
        let adam = Adam::new(&params);
        Ok(Self {
            method,
            shape,
            base: BackendParams::default(),
            params,
            idx: ParamIdx { tok, pos, layers, wout, bout },
            adam,
            steps_done: 0,
        })
    }

    /// Build the forward tape for one packed `(batch, seqlen)` token
    /// buffer.  Leaves the parameters at node ids `0..params.len()`
    /// (creation order), so [`Tape::backward`]'s leaf grads map back
    /// to parameters by index.
    fn forward(
        &self,
        tape: &mut Tape,
        tokens: &[i32],
        labels: &[i32],
        weights: &[f32],
        batch: usize,
    ) -> Result<ForwardRefs> {
        let n = self.shape.seqlen;
        if tokens.len() != batch * n {
            bail!("native {}: {} tokens, expected {}x{}", self.method.name(), tokens.len(), batch, n);
        }
        for p in &self.params {
            tape.leaf(p.clone());
        }
        let mut x = tape.embed(self.idx.tok, self.idx.pos, tokens, n);
        let mut layer_qk = Vec::with_capacity(self.idx.layers.len());
        for l in &self.idx.layers {
            let qn = tape.matmul(x, l.wq);
            let kn = tape.matmul(x, l.wk);
            let vn = tape.matmul(x, l.wv);
            let att = tape
                .attention(qn, kn, vn, l.alpha, l.beta, self.method, self.base, batch)
                .map_err(|e| anyhow!(e))?;
            let proj = tape.matmul(att, l.wo);
            let res1 = tape.add(x, proj);
            let x1 = tape.layernorm(res1, l.ln1_g, l.ln1_b);
            let h1m = tape.matmul(x1, l.w1);
            let h1b = tape.add_bias(h1m, l.b1);
            let h1 = tape.relu(h1b);
            let h2m = tape.matmul(h1, l.w2);
            let h2 = tape.add_bias(h2m, l.b2);
            let res2 = tape.add(x1, h2);
            x = tape.layernorm(res2, l.ln2_g, l.ln2_b);
            layer_qk.push((qn, kn));
        }
        let lg = tape.matmul(x, self.idx.wout);
        let logits = tape.add_bias(lg, self.idx.bout);
        let loss = tape.mlm_loss(logits, labels, weights);
        Ok(ForwardRefs { loss, layer_qk })
    }

    /// Per-layer `[alpha, beta, sigma_q, sigma_k]` from a built tape —
    /// the fig. 9 telemetry row (alpha/beta are 0 for non-LLN methods,
    /// matching the AOT driver's convention).
    fn layer_stats(&self, tape: &Tape, refs: &ForwardRefs) -> Vec<[f32; 4]> {
        self.idx
            .layers
            .iter()
            .zip(&refs.layer_qk)
            .map(|(l, &(qn, kn))| {
                let sq = vec_ops::std(tape.val(qn).data()) as f32;
                let sk = vec_ops::std(tape.val(kn).data()) as f32;
                if self.method == Method::Lln {
                    [self.params[l.alpha].get(0, 0), self.params[l.beta].get(0, 0), sq, sk]
                } else {
                    [0.0, 0.0, sq, sk]
                }
            })
            .collect()
    }

    /// Per-layer `(attention matrix, (sigma_q, sigma_k))` for a single
    /// probe sequence of `seqlen` tokens — the native fig. 1 probe
    /// (dense matrices come from the backend's `explicit_matrix` with
    /// the layer's *current* alpha/beta).
    pub fn probe_layers(&self, tokens: &[i32]) -> Result<Vec<(Mat, (f64, f64))>> {
        let n = self.shape.seqlen;
        if tokens.len() != n {
            bail!("probe wants one sequence of {n} tokens, got {}", tokens.len());
        }
        let mut tape = Tape::new();
        let weights = vec![0.0f32; n];
        let refs = self.forward(&mut tape, tokens, tokens, &weights, 1)?;
        let mut out = Vec::with_capacity(self.idx.layers.len());
        for (l, &(qn, kn)) in self.idx.layers.iter().zip(&refs.layer_qk) {
            let q = tape.val(qn);
            let k = tape.val(kn);
            let backend = backend_for(
                self.method,
                BackendParams {
                    alpha: self.params[l.alpha].get(0, 0),
                    beta: self.params[l.beta].get(0, 0),
                    ..self.base
                },
            );
            let p = backend
                .explicit_matrix(q, k, &AttnSpec::FULL)
                .ok_or_else(|| anyhow!("{} has no dense matrix to probe", self.method.name()))?;
            out.push((p, (vec_ops::std(q.data()), vec_ops::std(k.data()))));
        }
        Ok(out)
    }
}

impl TrainStep for NativeStep {
    fn name(&self) -> String {
        format!(
            "native:{} (L={} d={} ff={} vocab={})",
            self.method.name(),
            self.shape.layers,
            self.shape.d_model,
            self.shape.ff,
            self.shape.vocab
        )
    }
    fn batch_shape(&self) -> (usize, usize) {
        (self.shape.batch, self.shape.seqlen)
    }
    fn vocab(&self) -> usize {
        self.shape.vocab
    }

    fn step(&mut self, lr: f64, batch: &MlmBatch) -> Result<StepTelemetry> {
        let mut tape = Tape::new();
        let refs =
            self.forward(&mut tape, &batch.tokens, &batch.labels, &batch.weights, batch.batch)?;
        let loss = tape.val(refs.loss).get(0, 0);
        if !loss.is_finite() {
            bail!("native {}: non-finite loss at step {}", self.method.name(), self.steps_done + 1);
        }
        let layer_stats = self.layer_stats(&tape, &refs);
        let mut grads = tape.backward(refs.loss);
        let mut gmats: Vec<Mat> = Vec::with_capacity(self.params.len());
        let mut gnorm2 = 0.0f64;
        for (i, p) in self.params.iter().enumerate() {
            let g = grads[i].take().unwrap_or_else(|| Mat::zeros(p.rows(), p.cols()));
            gnorm2 += g.data().iter().map(|&x| x as f64 * x as f64).sum::<f64>();
            gmats.push(g);
        }
        self.adam.step(&mut self.params, &gmats, lr);
        self.steps_done += 1;
        Ok(StepTelemetry {
            step: self.steps_done,
            loss,
            grad_norm: gnorm2.sqrt() as f32,
            layer_stats,
        })
    }

    fn eval_loss(&mut self, batch: &MlmBatch) -> Result<f32> {
        let mut tape = Tape::new();
        let refs =
            self.forward(&mut tape, &batch.tokens, &batch.labels, &batch.weights, batch.batch)?;
        Ok(tape.val(refs.loss).get(0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;

    fn tiny_shape() -> NativeShape {
        NativeShape { batch: 2, seqlen: 32, d_model: 16, layers: 1, ff: 32, vocab: 256, seed: 3 }
    }

    /// Finite-difference check of one tape op pipeline: perturb a leaf
    /// coordinate, compare the loss delta against the tape gradient.
    fn tape_fd_check(build: impl Fn(&mut Tape, &[Mat]) -> usize, leaves: Vec<Mat>, tol: f32) {
        let mut tape = Tape::new();
        for l in &leaves {
            tape.leaf(l.clone());
        }
        let loss = build(&mut tape, &leaves);
        assert_eq!(tape.val(loss).shape(), (1, 1));
        let grads = tape.backward(loss);
        let h = 1e-2f32;
        for (li, leaf) in leaves.iter().enumerate() {
            let g = grads[li].as_ref().expect("leaf grad");
            // Spot-check a few coordinates per leaf.
            for ci in 0..leaf.data().len().min(3) {
                let fd = {
                    let run = |delta: f32| {
                        let mut tape2 = Tape::new();
                        for (j, l) in leaves.iter().enumerate() {
                            let mut m = l.clone();
                            if j == li {
                                m.data_mut()[ci] += delta;
                            }
                            tape2.leaf(m);
                        }
                        let id = build(&mut tape2, &leaves);
                        tape2.val(id).get(0, 0)
                    };
                    (run(h) - run(-h)) / (2.0 * h)
                };
                let got = g.data()[ci];
                assert!(
                    (got - fd).abs() <= tol * (1.0 + fd.abs()),
                    "leaf {li} coord {ci}: tape {got} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn tape_matmul_layernorm_chain_matches_finite_differences() {
        let mut rng = Pcg64::seed(11);
        let a = Mat::gaussian(3, 4, 0.7, &mut rng);
        let b = Mat::gaussian(4, 4, 0.7, &mut rng);
        let g = Mat::from_vec(1, 4, vec![1.1, 0.9, 1.0, 1.2]);
        let s = Mat::zeros(1, 4);
        tape_fd_check(
            |tape, _| {
                // leaves: a, b, g, s (ids 0..4).  Smooth ops only — a
                // ReLU kink near zero would poison the central
                // differences; relu is covered by the training tests.
                let m = tape.matmul(0, 1);
                let ln = tape.layernorm(m, 2, 3);
                let bias = tape.add_bias(ln, 3);
                // Reduce to a scalar via mlm_loss over 3 "classes"-wide rows.
                tape.mlm_loss(bias, &[0, 1, 2], &[1.0, 0.5, 1.0])
            },
            vec![a, b, g, s],
            5e-2,
        );
    }

    #[test]
    fn tape_embed_scatter_accumulates() {
        let mut tape = Tape::new();
        let table = tape.leaf(Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        let pos = tape.leaf(Mat::zeros(2, 2));
        let x = tape.embed(table, pos, &[1, 1, 2, 1], 2);
        assert_eq!(tape.val(x).row(0), &[3.0, 4.0]);
        // Scalarize: sum everything via a weighted loss surrogate —
        // use mlm_loss with uniform labels for a quick backward.
        let loss = tape.mlm_loss(x, &[0, 0, 0, 0], &[1.0; 4]);
        let grads = tape.backward(loss);
        let dt = grads[table].as_ref().unwrap();
        // Token 1 appears 3x, token 2 once, token 0 never.
        assert!(dt.row(0).iter().all(|&v| v == 0.0));
        assert!(dt.row(1).iter().any(|&v| v != 0.0));
        assert!(dt.row(2).iter().any(|&v| v != 0.0));
        let dp = grads[pos].as_ref().unwrap();
        assert_eq!(dp.shape(), (2, 2));
    }

    #[test]
    fn native_training_reduces_loss_for_softmax_and_lln() {
        for method in [Method::Softmax, Method::Lln] {
            let mut step = NativeStep::new(method, tiny_shape()).unwrap();
            let (b, n) = step.batch_shape();
            let mut corpus = Corpus::new(step.vocab(), 5);
            let mut first = None;
            let mut last = 0.0f32;
            for _ in 0..12 {
                let batch = corpus.mlm_batch(b, n, 0.15);
                let out = step.step(2e-2, &batch).unwrap();
                assert!(out.loss.is_finite() && out.grad_norm.is_finite(), "{method:?}");
                assert!(out.grad_norm > 0.0, "{method:?}: zero grad norm");
                if first.is_none() {
                    first = Some(out.loss);
                }
                last = out.loss;
            }
            let first = first.unwrap();
            assert!(
                last < first - 0.05,
                "{method:?}: loss should drop: first={first} last={last}"
            );
        }
    }

    #[test]
    fn lln_alpha_beta_are_learned() {
        let mut step = NativeStep::new(Method::Lln, tiny_shape()).unwrap();
        let (b, n) = step.batch_shape();
        let mut corpus = Corpus::new(step.vocab(), 9);
        let init = step.params[step.idx.layers[0].alpha].get(0, 0);
        let mut tel = None;
        for _ in 0..8 {
            let batch = corpus.mlm_batch(b, n, 0.15);
            tel = Some(step.step(5e-2, &batch).unwrap());
        }
        let now = step.params[step.idx.layers[0].alpha].get(0, 0);
        assert!(now != init, "alpha never moved: {init} -> {now}");
        let tel = tel.unwrap();
        assert_eq!(tel.layer_stats.len(), 1);
        assert!(tel.layer_stats[0][0] > 0.0, "telemetry must carry alpha");
        assert!(tel.layer_stats[0][2] > 0.0, "telemetry must carry sigma_q");
    }

    #[test]
    fn eval_loss_is_deterministic_and_step_count_advances() {
        let mut step = NativeStep::new(Method::Softmax, tiny_shape()).unwrap();
        let (b, n) = step.batch_shape();
        let mut corpus = Corpus::new(step.vocab(), 6);
        let batch = corpus.mlm_batch(b, n, 0.15);
        let a = step.eval_loss(&batch).unwrap();
        let b2 = step.eval_loss(&batch).unwrap();
        assert_eq!(a, b2);
        step.step(1e-3, &batch).unwrap();
        let c = step.eval_loss(&batch).unwrap();
        assert_ne!(a, c, "a step must change the model");
    }

    #[test]
    fn native_step_rejects_untrainable_methods() {
        for m in [Method::Nystrom, Method::Linformer, Method::LlnDiag, Method::Performer] {
            let err = NativeStep::new(m, tiny_shape()).unwrap_err();
            assert!(format!("{err}").contains("backward"), "{m:?}");
        }
    }

    #[test]
    fn probe_layers_returns_stochastic_matrices() {
        let step = NativeStep::new(Method::Softmax, tiny_shape()).unwrap();
        let mut corpus = Corpus::new(step.vocab(), 7);
        let tokens = corpus.mlm_batch(1, 32, 0.0).labels;
        let probed = step.probe_layers(&tokens).unwrap();
        assert_eq!(probed.len(), 1);
        let (p, (sq, sk)) = &probed[0];
        assert_eq!(p.shape(), (32, 32));
        assert!(p.is_stochastic(1e-3));
        assert!(*sq > 0.0 && *sk > 0.0);
    }
}
