//! Statistics instruments: entropy, histograms, log-normal fitting, and
//! distribution distances — the measurement half of the paper's §3.
//!
//! Everything operates on the stochastic matrices produced by
//! [`crate::attention`] or fetched from the PJRT probe artifacts.

use crate::tensor::Mat;

/// Shannon entropy (bits) of one probability row (paper eq. 20).
pub fn row_entropy(p: &[f32]) -> f64 {
    let mut h = 0.0f64;
    for &x in p {
        if x > 0.0 {
            let x = x as f64;
            h -= x * x.log2();
        }
    }
    h
}

/// Mean row entropy of a stochastic matrix (paper eq. 7).
pub fn attention_entropy(p: &Mat) -> f64 {
    (0..p.rows()).map(|i| row_entropy(p.row(i))).sum::<f64>() / p.rows() as f64
}

/// Shannon entropy in nats of one probability row (so the uniform row
/// over n entries scores exactly ln(n)).
pub fn row_entropy_nats(p: &[f32]) -> f64 {
    row_entropy(p) * std::f64::consts::LN_2
}

/// Mean row entropy in nats of a stochastic matrix.
pub fn attention_entropy_nats(p: &Mat) -> f64 {
    attention_entropy(p) * std::f64::consts::LN_2
}

/// Row-variance of a stochastic matrix averaged over rows (paper eq. 21).
pub fn attention_row_variance(p: &Mat) -> f64 {
    let n = p.cols() as f64;
    let mut total = 0.0f64;
    for i in 0..p.rows() {
        let row = p.row(i);
        let mu = 1.0 / n; // stochastic rows have mean exactly 1/N
        total += row.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / n;
    }
    total / p.rows() as f64
}

/// Variance of log-entries — the "sigma^2" of the log-normal model
/// (what moment matching equalizes, paper fig. 5).
pub fn log_variance(p: &Mat, eps: f64) -> f64 {
    let logs: Vec<f64> = p.data().iter().map(|&x| ((x as f64).max(eps)).ln()).collect();
    let mu = logs.iter().sum::<f64>() / logs.len() as f64;
    logs.iter().map(|&x| (x - mu).powi(2)).sum::<f64>() / logs.len() as f64
}

/// Mean of log-entries (the log-normal "mu", paper Prop 3.1).
pub fn log_mean(p: &Mat, eps: f64) -> f64 {
    p.data().iter().map(|&x| ((x as f64).max(eps)).ln()).sum::<f64>() / p.data().len() as f64
}

/// Summary of a fitted log-normal: parameters of ln X ~ N(mu, sigma^2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogNormalFit {
    pub mu: f64,
    pub sigma2: f64,
}

/// Fit a log-normal by moments of the log (MLE for log-normal data).
pub fn fit_log_normal(samples: &[f32], eps: f64) -> LogNormalFit {
    let logs: Vec<f64> = samples.iter().map(|&x| ((x as f64).max(eps)).ln()).collect();
    let mu = logs.iter().sum::<f64>() / logs.len() as f64;
    let sigma2 = logs.iter().map(|&x| (x - mu).powi(2)).sum::<f64>() / logs.len() as f64;
    LogNormalFit { mu, sigma2 }
}

/// Histogram with fixed bin edges over [lo, hi]; out-of-range clamps to
/// the edge bins (used for fig. 7's attention-weight histograms).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let idx = (t.max(0.0) as usize).min(bins - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn add_all(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    /// Normalized density per bin.
    pub fn density(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let norm = (self.total.max(1) as f64) * w;
        self.counts.iter().map(|&c| c as f64 / norm).collect()
    }

    pub fn bin_centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len()).map(|i| self.lo + w * (i as f64 + 0.5)).collect()
    }
}

/// Two-sample Kolmogorov–Smirnov distance (distribution similarity for
/// fig. 7's SA-vs-LLN comparison).
pub fn ks_distance(a: &[f32], b: &[f32]) -> f64 {
    let mut xa: Vec<f32> = a.to_vec();
    let mut xb: Vec<f32> = b.to_vec();
    xa.sort_by(|p, q| p.partial_cmp(q).unwrap());
    xb.sort_by(|p, q| p.partial_cmp(q).unwrap());
    let (na, nb) = (xa.len() as f64, xb.len() as f64);
    let (mut i, mut j) = (0usize, 0usize);
    let mut d = 0.0f64;
    while i < xa.len() && j < xb.len() {
        let (va, vb) = (xa[i], xb[j]);
        if va <= vb {
            i += 1;
        }
        if vb <= va {
            j += 1;
        }
        d = d.max((i as f64 / na - j as f64 / nb).abs());
    }
    d
}

/// Ordinary least squares y = a x + b; returns (a, b, r^2).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&xi, &yi) in x.iter().zip(y) {
        sxx += (xi - mx) * (xi - mx);
        sxy += (xi - mx) * (yi - my);
        syy += (yi - my) * (yi - my);
    }
    let a = sxy / sxx;
    let b = my - a * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Streaming mean/variance (Welford) for metric pipelines.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile of a sample (linear interpolation), q in [0, 100].
///
/// An empty sample yields 0.0 — callers report "no traffic yet" without
/// guarding — and the sort uses `total_cmp`, so a stray NaN orders to
/// the end instead of panicking mid-sort.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::Mat;

    #[test]
    fn entropy_uniform_is_log2_n() {
        let n = 64;
        let p = Mat::from_vec(1, n, vec![1.0 / n as f32; n]);
        assert!((attention_entropy(&p) - 6.0).abs() < 1e-6);
    }

    #[test]
    fn entropy_of_uniform_stochastic_matrix_is_ln_n() {
        for n in [4usize, 17, 64, 256] {
            let p = Mat::from_vec(3, n, vec![1.0 / n as f32; 3 * n]);
            let h = attention_entropy_nats(&p);
            assert!(
                (h - (n as f64).ln()).abs() < 1e-4,
                "n={n}: {h} vs ln(n)={}",
                (n as f64).ln()
            );
            // Bits/nats agree up to the ln 2 factor.
            assert!((attention_entropy(&p) * std::f64::consts::LN_2 - h).abs() < 1e-12);
        }
    }

    #[test]
    fn entropy_onehot_is_zero() {
        let mut row = vec![0.0f32; 16];
        row[3] = 1.0;
        assert!(row_entropy(&row).abs() < 1e-12);
    }

    #[test]
    fn entropy_bounds() {
        let mut rng = Pcg64::seed(1);
        let mut p = Mat::gaussian(8, 32, 1.0, &mut rng);
        p.softmax_rows();
        let h = attention_entropy(&p);
        assert!(h > 0.0 && h < 5.0 + 1e-9); // log2(32) = 5
    }

    #[test]
    fn log_normal_fit_recovers_parameters() {
        let mut rng = Pcg64::seed(2);
        let (mu, sigma) = (-2.0f64, 0.7f64);
        let samples: Vec<f32> = (0..50_000)
            .map(|_| ((mu + sigma * rng.gauss()).exp()) as f32)
            .collect();
        let fit = fit_log_normal(&samples, 1e-30);
        assert!((fit.mu - mu).abs() < 0.02, "mu {}", fit.mu);
        assert!((fit.sigma2 - sigma * sigma).abs() < 0.02, "s2 {}", fit.sigma2);
    }

    #[test]
    fn histogram_density_integrates_to_one() {
        let mut rng = Pcg64::seed(3);
        let mut h = Histogram::new(-4.0, 4.0, 40);
        h.add_all((0..10_000).map(|_| rng.gauss()));
        let w = 8.0 / 40.0;
        let total: f64 = h.density().iter().map(|d| d * w).sum();
        assert!((total - 1.0).abs() < 0.02, "{total}"); // tails clamp in
    }

    #[test]
    fn ks_same_distribution_small() {
        let mut rng = Pcg64::seed(4);
        let a: Vec<f32> = (0..5000).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..5000).map(|_| rng.gauss() as f32).collect();
        assert!(ks_distance(&a, &b) < 0.05);
    }

    #[test]
    fn ks_different_distribution_large() {
        let mut rng = Pcg64::seed(5);
        let a: Vec<f32> = (0..5000).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..5000).map(|_| rng.gauss() as f32 + 2.0).collect();
        assert!(ks_distance(&a, &b) > 0.5);
    }

    #[test]
    fn linear_fit_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 3.0 * v - 1.0).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9 && (b + 1.0).abs() < 1e-9 && (r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_degenerate_inputs() {
        // Empty window (a class with no traffic yet) reports 0, not a panic.
        assert_eq!(percentile(&[], 50.0), 0.0);
        // A NaN sample orders via total_cmp instead of panicking the sort;
        // the finite percentiles stay finite.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!(percentile(&xs, 0.0).is_finite());
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
    }
}
