//! The unified attention-backend abstraction.
//!
//! [`AttentionBackend`] is the one dispatch surface every caller uses —
//! the serving workers, the benches, the experiment harnesses, and the
//! fig. 2 analysis sweeps — instead of per-call-site `match` arms over
//! [`Method`].  Each implementation wires the method's *fast* path
//! (fused O(n·tile) streaming-softmax for the exact class,
//! register-blocked + multi-threaded matmuls, chunked O(N) streaming
//! for the linear class) while the free functions in
//! [`kernels`](super::kernels) remain the single-threaded reference
//! formulation that the property suite (`rust/tests/prop_kernels.rs`)
//! pins the fast paths against.  (Since the register-blocked
//! microkernels landed, those free functions route their matmuls
//! through [`tensor::micro`](crate::tensor::micro) too; the *scalar*
//! anchors are `Mat::matmul_ref` / `Mat::matmul_t_ref`, which the
//! parity suite pins the microkernels against separately.)
//!
//! To add a method: implement the trait, register it in
//! [`backend_for`], add the `Method` variant, and extend the parity
//! properties — see ROADMAP.md "Open items" for the checklist.

use super::decode::{DecodeState, KvCache, PrefixState};
use super::grad;
use super::kernels::{
    blockdiag_attention_matrix_spec, blockdiag_decode_step_dispatch, clamped_exp, elu_features,
    fused_quadratic_attention_dispatch, fused_quadratic_decode_step_dispatch,
    fused_softmax_attention_dispatch, fused_softmax_decode_step_dispatch,
    linear_attention_matrix_spec, linear_attention_spec_dispatch, lln_features, nystrom_attention,
    par_blockdiag_attention_spec, performer_features, performer_projection,
    quadratic_attention_matrix_spec, softmax_attention_matrix_spec,
};
use super::{AttnSpec, Method};
use crate::lowp::{dequantize, quant_params, quantize, Precision};
use crate::tensor::{KernelDispatch, Mat};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Tuning knobs shared by every backend (see
/// [`ComputeConfig`](crate::config::ComputeConfig) for the config-file
/// surface).  `threads == 0` / `chunk == 0` mean "auto".
#[derive(Clone, Copy, Debug)]
pub struct BackendParams {
    /// LLN feature-map exponents (paper eq. 8-10).
    pub alpha: f32,
    pub beta: f32,
    /// Diagonal tile size for BlockDiag / LLN+Diag.
    pub block: usize,
    /// Nystrom landmark count.
    pub landmarks: usize,
    /// Performer feature count (0 = head dim).
    pub features: usize,
    /// Linformer projected sequence length.
    pub kproj: usize,
    /// Seed for deterministic projections (Performer, Linformer).
    pub seed: u64,
    /// Scoped-worker count for the parallel kernels (0 = auto).
    pub threads: usize,
    /// Streaming work-partition granularity for the linear class: k/v
    /// rows are split across workers in multiples of this (0 = auto).
    pub chunk: usize,
    /// K/V tile rows for the fused O(n·tile) exact kernels (0 = auto:
    /// [`DEFAULT_FUSED_TILE`](super::kernels::DEFAULT_FUSED_TILE)).
    pub tile: usize,
    /// Query rows per register block in the fused kernels (0 = auto).
    pub unroll: usize,
    /// Route the exact quadratic-cost forwards (Softmax, Quadratic)
    /// through the fused streaming kernels instead of materializing the
    /// n×n score matrix.  On by default; turn off to get the
    /// bitwise-reproducible materialized pipeline.
    pub fused: bool,
    /// Declared head dim for kernel monomorphization (0 = resolve per
    /// call from the actual operand width).  When it names a
    /// specialized instance (32/64/128) the dispatch is pinned at
    /// construction; any other value pins the generic fallback — see
    /// [`KernelDispatch::for_dim`].
    pub head_dim: usize,
    /// K/V storage precision for decode caches and at-rest operands.
    /// `F32` (the default) is the bitwise escape hatch: every path is
    /// identical to a build without the precision layer.  Arithmetic
    /// always accumulates in f32 regardless.
    pub precision: Precision,
    /// Resolved kernel-dispatch table entry (derived from `head_dim`
    /// by [`backend_for`]; not a config knob itself).
    pub kernel: KernelDispatch,
}

impl Default for BackendParams {
    fn default() -> Self {
        Self {
            alpha: 1.0,
            beta: 1.0,
            block: 64,
            landmarks: 32,
            features: 0,
            kproj: 64,
            seed: 7,
            threads: 0,
            chunk: 0,
            tile: 0,
            unroll: 0,
            fused: true,
            head_dim: 0,
            precision: Precision::F32,
            kernel: KernelDispatch::Auto,
        }
    }
}

impl BackendParams {
    /// Pull worker-count / blocking / fused-kernel knobs from the
    /// launcher config.  Also forwards `pool_threads` to the persistent
    /// compute pool — a no-op once the pool has spun up, so the first
    /// config to reach a kernel wins (matching the lazy-init contract).
    pub fn from_compute(c: &crate::config::ComputeConfig) -> Self {
        crate::util::compute_pool::configure(c.pool_threads);
        Self {
            threads: c.threads,
            block: c.block,
            chunk: c.chunk,
            tile: c.tile,
            unroll: c.unroll,
            fused: c.fused,
            head_dim: c.head_dim,
            precision: c.precision,
            ..Default::default()
        }
    }

    /// Resolve the kernel-dispatch entry from `head_dim`: 0 keeps the
    /// per-call `Auto` lookup; a declared dim pins its monomorphized
    /// instance (or the generic fallback) once, at construction.
    fn resolve_kernel(mut self) -> Self {
        self.kernel = if self.head_dim == 0 {
            KernelDispatch::Auto
        } else {
            KernelDispatch::for_dim(self.head_dim)
        };
        self
    }
}

/// Activations a training forward saves for its backward — the
/// recompute-light counterpart of the stored n×n score matrix (fused
/// softmax keeps only the per-row online statistics; the linear class
/// keeps the lifted feature maps).  Produced by
/// [`AttentionBackend::forward_train`], consumed by
/// [`AttentionBackend::backward`]; the variants are method-class
/// specific and not interchangeable.
pub enum AttnCache {
    /// Fused softmax: per-row online max / sum + the forward output.
    Softmax { row_max: Vec<f32>, row_sum: Vec<f32>, out: Mat },
    /// Linear class: the lifted feature maps + the forward output.
    Linear { phi_q: Mat, phi_k: Mat, out: Mat },
    /// Quadratic kernel: per-row denominators + the forward output.
    Quadratic { den: Vec<f32>, out: Mat },
    /// Block-diagonal softmax tiles: tile-concatenated per-row online
    /// stats + the tile forward output.
    BlockDiag { row_max: Vec<f32>, row_sum: Vec<f32>, out: Mat },
    /// LLN+Diag hybrid: the linear half's feature maps and output plus
    /// the diagonal half's tile stats and output (the published forward
    /// is their average).  When the tile does not divide N the backend
    /// degrades to a plain `Linear` cache instead, mirroring `forward`.
    LlnDiag {
        phi_q: Mat,
        phi_k: Mat,
        long_out: Mat,
        row_max: Vec<f32>,
        row_sum: Vec<f32>,
        diag_out: Mat,
    },
}

/// Input-side gradients of one attention forward, as returned by
/// [`AttentionBackend::backward`].  `dalpha`/`dbeta` are the LLN
/// feature-map exponent gradients (exactly 0.0 for every other
/// method), which is how the native trainer learns the paper's fig. 9
/// alpha/beta trajectories.
pub struct AttnGrads {
    pub dq: Mat,
    pub dk: Mat,
    pub dv: Mat,
    pub dalpha: f32,
    pub dbeta: f32,
}

/// One attention method behind a uniform interface.  Every entry point
/// carries an [`AttnSpec`] — causal flag, optional key-length padding
/// mask, score scale — so kernels, serving, benches, and the analysis
/// sweeps speak one mask vocabulary; pass [`AttnSpec::FULL`] for the
/// historical full-bidirectional behavior.
///
/// Methods whose structure cannot honor a mask (Nystrom, Linformer —
/// see [`Method::supports_masking`]) panic on non-full specs rather
/// than silently attending across the mask; callers that take
/// user-supplied specs should gate on [`Method::supports_spec`] first.
pub trait AttentionBackend: Send + Sync {
    /// The [`Method`] this backend implements.
    fn method(&self) -> Method;

    /// Stable display name (matches [`Method::name`]).
    fn name(&self) -> &'static str {
        self.method().name()
    }

    /// Fast-path forward pass: (n, d) q/k, (n, dv) v -> (n, dv), under
    /// the spec's mask.
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat;

    /// Dense row-stochastic attention matrix under the spec's mask,
    /// when the method has one (None for Nystrom/Linformer, whose
    /// mixing is implicit).  For every `Some`,
    /// `forward(q, k, v, spec) ~= explicit_matrix(q, k, spec) @ v` —
    /// the parity invariant the property suite enforces.
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat>;

    /// Analytic forward-pass flop count at sequence length `n`, head
    /// dim `d` (the Table 2 "time" column's model).  Quadratic-class
    /// models charge only the spec's live score pairs (~half under
    /// causal); linear-class models charge every live key once (causal
    /// changes nothing — the O(N) story — while `key_len` drops the
    /// dead key rows).
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64;

    /// Open an incremental causal decode session: the state that
    /// [`decode_step`](Self::decode_step) advances one token at a time
    /// (KV cache for the exact quadratic-cost class, the O(m·dv)
    /// `Σ φ(k)vᵀ` prefix state for the linear class).  `d` is the q/k
    /// head dim, `dv` the value dim.  Returns `Err` — never panics —
    /// for methods that cannot honor the causal mask
    /// ([`Method::supports_masking`] = false): the serving session
    /// path surfaces this per request through the coordinator response.
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        let _ = (d, dv);
        Err(format!(
            "{} attention has no incremental decode form (its mixing spans every position, so \
             it cannot honor the causal mask)",
            self.name()
        ))
    }

    /// Append token `t`'s (q, k, v) rows to the session state and
    /// return its attention output over the inclusive prefix `0..=t` —
    /// row `t` of the causal batch forward, without re-paying the
    /// prefix.  For the linear class this is bitwise identical to the
    /// chunked [`linear_attention_causal`](super::linear_attention_causal)
    /// rows (same chunk carry); for the cache class it matches to
    /// streaming-softmax tolerance.  Panics on a state built by a
    /// different method class — states are not interchangeable.
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let _ = (state, q, k, v);
        unreachable!("{}: decode_step without a decode state (begin_decode errs)", self.name())
    }

    /// Training forward: like [`forward`](Self::forward) but also
    /// returns the [`AttnCache`] its [`backward`](Self::backward)
    /// needs.  Returns `Err` — never panics — for methods with no
    /// native backward (Nystrom/Linformer, whose mixing has no
    /// recompute-light cache): the native trainer surfaces the message
    /// instead of killing a training run, mirroring
    /// [`begin_decode`](Self::begin_decode).
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let _ = (q, k, v, spec);
        Err(format!(
            "{} attention has no native backward pass; train it through AOT artifacts, or pick \
             one of softmax/lln/lln_diag/elu/relu/quadratic/performer/blockdiag",
            self.name()
        ))
    }

    /// Backward of [`forward_train`](Self::forward_train): input-side
    /// gradients given the saved cache and the output cotangent.
    /// `Err` for methods without a native backward, and for a cache of
    /// the wrong method class.
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let _ = (q, k, v, spec, cache, d_out);
        Err(format!(
            "{} attention has no native backward pass; train it through AOT artifacts, or pick \
             one of softmax/lln/lln_diag/elu/relu/quadratic/performer/blockdiag",
            self.name()
        ))
    }
}

/// Uniform `Err` for a [`AttnCache`] that reaches a backward of a
/// different method class.
fn wrong_cache(method: Method) -> String {
    format!("{}: backward on a cache of a different method class", method.name())
}

/// Shared linear-class backward: φ-space reverse sweep + a per-method
/// feature chain rule mapping `dφ` back to the raw inputs.  `chunk` /
/// `threads` feed the pooled reverse sweep; `threads <= 1` keeps the
/// serial path bitwise.
#[allow(clippy::too_many_arguments)]
fn linear_backward(
    method: Method,
    v: &Mat,
    spec: &AttnSpec,
    cache: &AttnCache,
    d_out: &Mat,
    chunk: usize,
    threads: usize,
    chain: impl Fn(&Mat, &Mat, &Mat, &Mat) -> (Mat, Mat, f32, f32),
) -> Result<AttnGrads, String> {
    let AttnCache::Linear { phi_q, phi_k, out } = cache else {
        return Err(wrong_cache(method));
    };
    let (d_phi_q, d_phi_k, dv) =
        grad::linear_attention_spec_bwd_par(phi_q, phi_k, v, spec, out, d_out, chunk, threads);
    let (dq, dk, dalpha, dbeta) = chain(phi_q, phi_k, &d_phi_q, &d_phi_k);
    Ok(AttnGrads { dq, dk, dv, dalpha, dbeta })
}

/// Panic with a uniform message when a [`DecodeState`] reaches a
/// backend of a different method class (a caller bug, not a request
/// error — session states are created by `begin_decode` and must be
/// stepped by the same backend).
fn wrong_state(method: Method) -> ! {
    panic!("{}: decode_step on a state of a different method class", method.name())
}

/// Panic with a uniform message when a mask reaches a method that
/// structurally cannot honor it.
fn require_full_spec(method: Method, spec: &AttnSpec) {
    assert!(
        spec.is_full(),
        "{} attention cannot honor causal/key_len masks (its mixing spans every position); \
         gate on Method::supports_spec",
        method.name()
    );
}

// ---------------------------------------------------------------------------
// Implementations
// ---------------------------------------------------------------------------

struct SoftmaxBackend(BackendParams);

impl AttentionBackend for SoftmaxBackend {
    fn method(&self) -> Method {
        Method::Softmax
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        if self.0.fused {
            // O(n·tile) streaming-softmax path: never builds the n×n
            // score matrix, which is what lets exact softmax serve and
            // bench honestly at 8k–16k tokens — under causal it also
            // streams only the prefix tiles (~half the score work).
            return fused_softmax_attention_dispatch(
                q, k, v, spec, self.0.tile, self.0.unroll, self.0.threads, self.0.kernel,
            );
        }
        if spec.is_full() && spec.scale.is_none() {
            // The bitwise-reproducible materialized pipeline.
            let d = q.cols();
            let mut scores = q.par_matmul_t(k, self.0.threads);
            let scale = 1.0 / (d as f32).sqrt();
            scores.map_inplace(|x| x * scale);
            scores.par_softmax_rows(self.0.threads);
            return scores.par_matmul(v, self.0.threads);
        }
        // Masked materialized route: parallel score matmul, then the
        // same per-row masked softmax the dense reference uses (rows
        // partitioned across the same worker pool).
        let mut scores = q.par_matmul_t(k, self.0.threads);
        super::kernels::par_masked_softmax_rows(
            &mut scores,
            k.rows(),
            spec,
            spec.resolve_scale(q.cols()),
            self.0.threads,
        );
        scores.par_matmul(v, self.0.threads)
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        Some(softmax_attention_matrix_spec(q, k, spec))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        (4.0 * d as f64 + 5.0) * spec.masked_pairs(n, n)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Cache(KvCache::with_precision(d, dv, self.0.precision)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let scale = 1.0 / (q.len() as f32).sqrt();
        match state {
            DecodeState::Cache(cache) => {
                cache.push(k, v);
                fused_softmax_decode_step_dispatch(
                    q,
                    cache.keys(),
                    cache.values(),
                    cache.len(),
                    cache.d(),
                    cache.dv(),
                    scale,
                    self.0.tile,
                    self.0.kernel,
                )
            }
            // Paged sessions gather their pages into contiguous scratch
            // and run the identical kernel — bitwise equal to Cache.
            DecodeState::Paged(cache) => {
                cache.push(k, v);
                let (len, d, dv, tile) = (cache.len(), cache.d(), cache.dv(), self.0.tile);
                let (keys, values) = cache.gather();
                fused_softmax_decode_step_dispatch(
                    q, keys, values, len, d, dv, scale, tile, self.0.kernel,
                )
            }
            _ => wrong_state(Method::Softmax),
        }
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let (out, row_max, row_sum) =
            grad::fused_softmax_attention_spec_fwd_train_par(q, k, v, spec, self.0.tile, self.0.threads);
        Ok((out.clone(), AttnCache::Softmax { row_max, row_sum, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let AttnCache::Softmax { row_max, row_sum, out } = cache else {
            return Err(wrong_cache(Method::Softmax));
        };
        let (dq, dk, dv) = grad::fused_softmax_attention_spec_bwd_par(
            q, k, v, spec, out, row_max, row_sum, d_out, self.0.tile, self.0.threads,
        );
        Ok(AttnGrads { dq, dk, dv, dalpha: 0.0, dbeta: 0.0 })
    }
}

struct LlnBackend(BackendParams);

impl AttentionBackend for LlnBackend {
    fn method(&self) -> Method {
        Method::Lln
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        linear_attention_spec_dispatch(
            &lln_features(q, self.0.alpha),
            &lln_features(k, self.0.beta),
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        )
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        Some(linear_attention_matrix_spec(
            &lln_features(q, self.0.alpha),
            &lln_features(k, self.0.beta),
            spec,
        ))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        linear_flops(n, d, spec)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Prefix(PrefixState::with_kernel(d, dv, self.0.chunk, self.0.kernel)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let DecodeState::Prefix(prefix) = state else { wrong_state(Method::Lln) };
        prefix.push(&lln_features_row(k, self.0.beta), v);
        prefix.read(&lln_features_row(q, self.0.alpha))
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let phi_q = lln_features(q, self.0.alpha);
        let phi_k = lln_features(k, self.0.beta);
        let out = linear_attention_spec_dispatch(
            &phi_q,
            &phi_k,
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        );
        Ok((out.clone(), AttnCache::Linear { phi_q, phi_k, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let (alpha, beta) = (self.0.alpha, self.0.beta);
        let (chunk, threads) = (self.0.chunk, self.0.threads);
        linear_backward(Method::Lln, v, spec, cache, d_out, chunk, threads, |phi_q, phi_k, dpq, dpk| {
            // The clamped-exp chain rule also produces dα/dβ — the
            // hooks that let alpha/beta be *learned* natively (fig. 9).
            let (dq, dalpha) = grad::lln_feature_bwd(q, phi_q, dpq, alpha);
            let (dk, dbeta) = grad::lln_feature_bwd(k, phi_k, dpk, beta);
            (dq, dk, dalpha, dbeta)
        })
    }
}

/// Row form of [`lln_features`] (same clamped-exp map per element) for
/// the decode step's single-token feature lift.
fn lln_features_row(x: &[f32], scale: f32) -> Vec<f32> {
    x.iter().map(|&v| clamped_exp(scale * v)).collect()
}

/// Row form of [`elu_features`].
fn elu_features_row(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v > 0.0 { v + 1.0 } else { v.exp() }).collect()
}

/// Linear-class flop model: the (2d² + 3d)·kl key-state build over the
/// spec's live keys plus the (2d² + 3d)·n query read-back.  Causal
/// masking leaves this unchanged (every live key is folded into the
/// prefix state exactly once); `key_len` drops the dead key rows.
fn linear_flops(n: usize, d: usize, spec: &AttnSpec) -> f64 {
    let df = d as f64;
    let kl = spec.key_limit(n) as f64;
    (kl + n as f64) * (2.0 * df * df + 3.0 * df)
}

struct LlnDiagBackend(BackendParams);

impl LlnDiagBackend {
    /// The diagonal softmax correction only exists when the tile
    /// divides N; otherwise both `forward` and `explicit_matrix`
    /// degrade identically to the long-range LLN path (the
    /// pre-registry analysis dispatch for LlnDiag), keeping the
    /// trait's forward-vs-matrix parity invariant total.
    fn tile_divides(&self, n: usize) -> bool {
        self.0.block != 0 && n % self.0.block == 0
    }
}

impl AttentionBackend for LlnDiagBackend {
    fn method(&self) -> Method {
        Method::LlnDiag
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        let mut out = linear_attention_spec_dispatch(
            &lln_features(q, self.0.alpha),
            &lln_features(k, self.0.beta),
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        );
        if !self.tile_divides(q.rows()) {
            return out;
        }
        let short = par_blockdiag_attention_spec(q, k, v, self.0.block, self.0.threads, spec);
        for (o, s) in out.data_mut().iter_mut().zip(short.data()) {
            *o = 0.5 * (*o + s);
        }
        out
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        let long = linear_attention_matrix_spec(
            &lln_features(q, self.0.alpha),
            &lln_features(k, self.0.beta),
            spec,
        );
        if !self.tile_divides(q.rows()) {
            return Some(long);
        }
        let short = blockdiag_attention_matrix_spec(q, k, self.0.block, spec);
        Some(long.add(&short).scale(0.5))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        linear_flops(n, d, spec)
            + (4.0 * d as f64 + 5.0) * super::blockdiag_masked_pairs(n, self.0.block, spec)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Hybrid {
            prefix: PrefixState::with_kernel(d, dv, self.0.chunk, self.0.kernel),
            cache: KvCache::with_precision(d, dv, self.0.precision),
        })
    }
    /// The decode session always applies the diagonal-tile correction
    /// (a session has no final length for the batch forward's
    /// tile-divides-N degrade check): step `t` matches the causal batch
    /// forward's row `t` whenever the tile divides the decoded length.
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let DecodeState::Hybrid { prefix, cache } = state else { wrong_state(Method::LlnDiag) };
        prefix.push(&lln_features_row(k, self.0.beta), v);
        let mut out = prefix.read(&lln_features_row(q, self.0.alpha));
        let block = self.0.block.max(1);
        // Same tile-window eviction as the BlockDiag session: the
        // short-range half only ever reads the current diagonal tile.
        if cache.len() > 0 && cache.len() % block == 0 {
            cache.start_new_window();
        }
        cache.push(k, v);
        let scale = 1.0 / (q.len() as f32).sqrt();
        let short = blockdiag_decode_step_dispatch(
            q,
            cache.keys(),
            cache.values(),
            cache.window_len(),
            cache.d(),
            cache.dv(),
            scale,
            block,
            self.0.kernel,
        );
        for (o, s) in out.iter_mut().zip(&short) {
            *o = 0.5 * (*o + s);
        }
        out
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let phi_q = lln_features(q, self.0.alpha);
        let phi_k = lln_features(k, self.0.beta);
        let long_out = linear_attention_spec_dispatch(
            &phi_q,
            &phi_k,
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        );
        if !self.tile_divides(q.rows()) {
            // Same degrade as `forward`: pure long-range LLN, so the
            // backward is exactly the LLN chain on a Linear cache.
            return Ok((long_out.clone(), AttnCache::Linear { phi_q, phi_k, out: long_out }));
        }
        let (diag_out, row_max, row_sum) = grad::blockdiag_attention_spec_fwd_train_par(
            q,
            k,
            v,
            spec,
            self.0.block,
            self.0.tile,
            self.0.threads,
        );
        let mut out = long_out.clone();
        for (o, s) in out.data_mut().iter_mut().zip(diag_out.data()) {
            *o = 0.5 * (*o + s);
        }
        Ok((out, AttnCache::LlnDiag { phi_q, phi_k, long_out, row_max, row_sum, diag_out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let (alpha, beta) = (self.0.alpha, self.0.beta);
        let (chunk, threads) = (self.0.chunk, self.0.threads);
        match cache {
            // Tile didn't divide N at forward time: the published
            // output was the pure LLN half, so its backward is too.
            AttnCache::Linear { .. } => linear_backward(
                Method::LlnDiag,
                v,
                spec,
                cache,
                d_out,
                chunk,
                threads,
                |phi_q, phi_k, dpq, dpk| {
                    let (dq, dalpha) = grad::lln_feature_bwd(q, phi_q, dpq, alpha);
                    let (dk, dbeta) = grad::lln_feature_bwd(k, phi_k, dpk, beta);
                    (dq, dk, dalpha, dbeta)
                },
            ),
            AttnCache::LlnDiag { phi_q, phi_k, long_out, row_max, row_sum, diag_out } => {
                // out = 0.5·(long + diag): each half sees half the
                // cotangent, and the input grads add.
                let half = d_out.scale(0.5);
                let (d_phi_q, d_phi_k, dv_long) = grad::linear_attention_spec_bwd_par(
                    phi_q, phi_k, v, spec, long_out, &half, chunk, threads,
                );
                let (dq_long, dalpha) = grad::lln_feature_bwd(q, phi_q, &d_phi_q, alpha);
                let (dk_long, dbeta) = grad::lln_feature_bwd(k, phi_k, &d_phi_k, beta);
                let (dq_diag, dk_diag, dv_diag) = grad::blockdiag_attention_spec_bwd_par(
                    q,
                    k,
                    v,
                    spec,
                    diag_out,
                    row_max,
                    row_sum,
                    &half,
                    self.0.block,
                    self.0.tile,
                    threads,
                );
                Ok(AttnGrads {
                    dq: dq_long.add(&dq_diag),
                    dk: dk_long.add(&dk_diag),
                    dv: dv_long.add(&dv_diag),
                    dalpha,
                    dbeta,
                })
            }
            _ => Err(wrong_cache(Method::LlnDiag)),
        }
    }
}

struct EluBackend(BackendParams);

impl AttentionBackend for EluBackend {
    fn method(&self) -> Method {
        Method::Elu
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        linear_attention_spec_dispatch(
            &elu_features(q),
            &elu_features(k),
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        )
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        Some(linear_attention_matrix_spec(&elu_features(q), &elu_features(k), spec))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        let df = d as f64;
        (spec.key_limit(n) + n) as f64 * (2.0 * df * df + 2.0 * df)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Prefix(PrefixState::with_kernel(d, dv, self.0.chunk, self.0.kernel)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let DecodeState::Prefix(prefix) = state else { wrong_state(Method::Elu) };
        prefix.push(&elu_features_row(k), v);
        prefix.read(&elu_features_row(q))
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let phi_q = elu_features(q);
        let phi_k = elu_features(k);
        let out = linear_attention_spec_dispatch(
            &phi_q,
            &phi_k,
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        );
        Ok((out.clone(), AttnCache::Linear { phi_q, phi_k, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let (chunk, threads) = (self.0.chunk, self.0.threads);
        linear_backward(Method::Elu, v, spec, cache, d_out, chunk, threads, |_, _, dpq, dpk| {
            (grad::elu_feature_bwd(q, dpq), grad::elu_feature_bwd(k, dpk), 0.0, 0.0)
        })
    }
}

struct ReluBackend(BackendParams);

impl AttentionBackend for ReluBackend {
    fn method(&self) -> Method {
        Method::Relu
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        let f = |m: &Mat| m.map(|x| x.max(0.0));
        linear_attention_spec_dispatch(
            &f(q),
            &f(k),
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        )
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        let f = |m: &Mat| m.map(|x| x.max(0.0));
        Some(linear_attention_matrix_spec(&f(q), &f(k), spec))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        let df = d as f64;
        (spec.key_limit(n) + n) as f64 * (2.0 * df * df + 2.0 * df)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Prefix(PrefixState::with_kernel(d, dv, self.0.chunk, self.0.kernel)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let DecodeState::Prefix(prefix) = state else { wrong_state(Method::Relu) };
        let relu = |x: &[f32]| x.iter().map(|&v| v.max(0.0)).collect::<Vec<f32>>();
        prefix.push(&relu(k), v);
        prefix.read(&relu(q))
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let f = |m: &Mat| m.map(|x| x.max(0.0));
        let phi_q = f(q);
        let phi_k = f(k);
        let out = linear_attention_spec_dispatch(
            &phi_q,
            &phi_k,
            v,
            spec,
            self.0.chunk,
            self.0.threads,
            self.0.kernel,
        );
        Ok((out.clone(), AttnCache::Linear { phi_q, phi_k, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let (chunk, threads) = (self.0.chunk, self.0.threads);
        linear_backward(Method::Relu, v, spec, cache, d_out, chunk, threads, |_, _, dpq, dpk| {
            (grad::relu_feature_bwd(q, dpq), grad::relu_feature_bwd(k, dpk), 0.0, 0.0)
        })
    }
}

struct QuadraticBackend(BackendParams);

impl AttentionBackend for QuadraticBackend {
    fn method(&self) -> Method {
        Method::Quadratic
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        if self.0.fused {
            return fused_quadratic_attention_dispatch(
                q, k, v, spec, self.0.tile, self.0.unroll, self.0.threads, self.0.kernel,
            );
        }
        quadratic_attention_matrix_spec(q, k, spec).par_matmul(v, self.0.threads)
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        Some(quadratic_attention_matrix_spec(q, k, spec))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        (4.0 * d as f64 + 4.0) * spec.masked_pairs(n, n)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Cache(KvCache::with_precision(d, dv, self.0.precision)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        match state {
            DecodeState::Cache(cache) => {
                cache.push(k, v);
                fused_quadratic_decode_step_dispatch(
                    q,
                    cache.keys(),
                    cache.values(),
                    cache.len(),
                    cache.d(),
                    cache.dv(),
                    self.0.tile,
                    self.0.kernel,
                )
            }
            DecodeState::Paged(cache) => {
                cache.push(k, v);
                let (len, d, dv, tile) = (cache.len(), cache.d(), cache.dv(), self.0.tile);
                let (keys, values) = cache.gather();
                fused_quadratic_decode_step_dispatch(
                    q, keys, values, len, d, dv, tile, self.0.kernel,
                )
            }
            _ => wrong_state(Method::Quadratic),
        }
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let (out, den) = grad::fused_quadratic_attention_spec_fwd_train_par(
            q, k, v, spec, self.0.tile, self.0.threads,
        );
        Ok((out.clone(), AttnCache::Quadratic { den, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let AttnCache::Quadratic { den, out } = cache else {
            return Err(wrong_cache(Method::Quadratic));
        };
        let (dq, dk, dv) = grad::fused_quadratic_attention_spec_bwd_par(
            q, k, v, spec, out, den, d_out, self.0.tile, self.0.threads,
        );
        Ok(AttnGrads { dq, dk, dv, dalpha: 0.0, dbeta: 0.0 })
    }
}

struct PerformerBackend {
    p: BackendParams,
    /// Projection per head dim — deterministic in (d, seed), built once
    /// and reused across forwards (serving calls this per request).
    proj_cache: Mutex<HashMap<usize, Arc<Mat>>>,
}

impl PerformerBackend {
    fn new(p: BackendParams) -> Self {
        Self { p, proj_cache: Mutex::new(HashMap::new()) }
    }

    fn proj(&self, d: usize) -> Arc<Mat> {
        let mut cache = self.proj_cache.lock().unwrap();
        cache
            .entry(d)
            .or_insert_with(|| {
                let m = if self.p.features == 0 { d } else { self.p.features };
                Arc::new(performer_projection(d, m, self.p.seed))
            })
            .clone()
    }
}

impl AttentionBackend for PerformerBackend {
    fn method(&self) -> Method {
        Method::Performer
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        let proj = self.proj(q.cols());
        linear_attention_spec_dispatch(
            &performer_features(q, proj.as_ref()),
            &performer_features(k, proj.as_ref()),
            v,
            spec,
            self.p.chunk,
            self.p.threads,
            self.p.kernel,
        )
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        let proj = self.proj(q.cols());
        Some(linear_attention_matrix_spec(
            &performer_features(q, proj.as_ref()),
            &performer_features(k, proj.as_ref()),
            spec,
        ))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        let (df, m) = (d as f64, if self.p.features == 0 { d } else { self.p.features } as f64);
        let (nf, kl) = (n as f64, spec.key_limit(n) as f64);
        // Feature maps over q rows + live k rows, state over live keys,
        // read-back over every query row.
        (nf + kl) * df * m + kl * (2.0 * m * df + 3.0 * m) + nf * (2.0 * m * df + 3.0 * m)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        let m = self.proj(d).cols();
        Ok(DecodeState::Prefix(PrefixState::with_kernel(m, dv, self.p.chunk, self.p.kernel)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let DecodeState::Prefix(prefix) = state else { wrong_state(Method::Performer) };
        let proj = self.proj(q.len());
        // The FAVOR+ lift needs the projection matmul; per-row results
        // are FP-identical to the batch feature map's rows.
        let lift = |x: &[f32]| {
            performer_features(&Mat::from_vec(1, x.len(), x.to_vec()), proj.as_ref())
                .data()
                .to_vec()
        };
        prefix.push(&lift(k), v);
        prefix.read(&lift(q))
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        let proj = self.proj(q.cols());
        let phi_q = performer_features(q, proj.as_ref());
        let phi_k = performer_features(k, proj.as_ref());
        let out = linear_attention_spec_dispatch(
            &phi_q,
            &phi_k,
            v,
            spec,
            self.p.chunk,
            self.p.threads,
            self.p.kernel,
        );
        Ok((out.clone(), AttnCache::Linear { phi_q, phi_k, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let proj = self.proj(q.cols());
        let (chunk, threads) = (self.p.chunk, self.p.threads);
        linear_backward(
            Method::Performer,
            v,
            spec,
            cache,
            d_out,
            chunk,
            threads,
            |phi_q, phi_k, dpq, dpk| {
                // The FAVOR+ projection is a fixed (seeded) operand, not
                // a parameter: only q/k receive gradients through the
                // clamped-exp feature lift.
                let dq = grad::performer_feature_bwd(q, phi_q, dpq, proj.as_ref());
                let dk = grad::performer_feature_bwd(k, phi_k, dpk, proj.as_ref());
                (dq, dk, 0.0, 0.0)
            },
        )
    }
}

struct NystromBackend(BackendParams);

impl AttentionBackend for NystromBackend {
    fn method(&self) -> Method {
        Method::Nystrom
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        require_full_spec(Method::Nystrom, spec);
        nystrom_attention(q, k, v, self.0.landmarks)
    }
    fn explicit_matrix(&self, _q: &Mat, _k: &Mat, _spec: &AttnSpec) -> Option<Mat> {
        None
    }
    fn flops_model(&self, n: usize, d: usize, _spec: &AttnSpec) -> f64 {
        let (nf, df, m) = (n as f64, d as f64, self.0.landmarks.min(n) as f64);
        4.0 * nf * m * df + 12.0 * 4.0 * m * m * m + 2.0 * nf * m * m
    }
}

struct BlockDiagBackend(BackendParams);

impl AttentionBackend for BlockDiagBackend {
    fn method(&self) -> Method {
        Method::BlockDiag
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        par_blockdiag_attention_spec(q, k, v, self.0.block, self.0.threads, spec)
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        Some(blockdiag_attention_matrix_spec(q, k, self.0.block, spec))
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        (4.0 * d as f64 + 5.0) * super::blockdiag_masked_pairs(n, self.0.block, spec)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        Ok(DecodeState::Cache(KvCache::with_precision(d, dv, self.0.precision)))
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        let block = self.0.block.max(1);
        let scale = 1.0 / (q.len() as f32).sqrt();
        match state {
            DecodeState::Cache(cache) => {
                // A token whose global index starts a new diagonal tile
                // never reads the previous tile's rows again: evict them
                // so the resident cache stays bounded by the tile window.
                if cache.len() > 0 && cache.len() % block == 0 {
                    cache.start_new_window();
                }
                cache.push(k, v);
                blockdiag_decode_step_dispatch(
                    q,
                    cache.keys(),
                    cache.values(),
                    cache.window_len(),
                    cache.d(),
                    cache.dv(),
                    scale,
                    block,
                    self.0.kernel,
                )
            }
            DecodeState::Paged(cache) => {
                if cache.len() > 0 && cache.len() % block == 0 {
                    cache.start_new_window();
                }
                cache.push(k, v);
                let (wl, d, dv) = (cache.window_len(), cache.d(), cache.dv());
                let (keys, values) = cache.gather();
                blockdiag_decode_step_dispatch(
                    q,
                    keys,
                    values,
                    wl,
                    d,
                    dv,
                    scale,
                    block,
                    self.0.kernel,
                )
            }
            _ => wrong_state(Method::BlockDiag),
        }
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        if self.0.block == 0 || q.rows() % self.0.block != 0 {
            // The inference kernel asserts this; training surfaces it
            // as a per-run Err instead of a panic.
            return Err(format!(
                "blockdiag training requires the tile ({}) to divide the sequence length ({}); \
                 set [compute] block accordingly",
                self.0.block,
                q.rows()
            ));
        }
        let (out, row_max, row_sum) = grad::blockdiag_attention_spec_fwd_train_par(
            q,
            k,
            v,
            spec,
            self.0.block,
            self.0.tile,
            self.0.threads,
        );
        Ok((out.clone(), AttnCache::BlockDiag { row_max, row_sum, out }))
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        let AttnCache::BlockDiag { row_max, row_sum, out } = cache else {
            return Err(wrong_cache(Method::BlockDiag));
        };
        let (dq, dk, dv) = grad::blockdiag_attention_spec_bwd_par(
            q,
            k,
            v,
            spec,
            out,
            row_max,
            row_sum,
            d_out,
            self.0.block,
            self.0.tile,
            self.0.threads,
        );
        Ok(AttnGrads { dq, dk, dv, dalpha: 0.0, dbeta: 0.0 })
    }
}

struct LinformerBackend {
    p: BackendParams,
    /// (E, F) sequence projections per length — deterministic in
    /// (n, seed), built once and reused across forwards.
    ef_cache: Mutex<HashMap<usize, Arc<(Mat, Mat)>>>,
}

impl LinformerBackend {
    fn new(p: BackendParams) -> Self {
        Self { p, ef_cache: Mutex::new(HashMap::new()) }
    }

    fn projections(&self, n: usize) -> Arc<(Mat, Mat)> {
        let mut cache = self.ef_cache.lock().unwrap();
        cache
            .entry(n)
            .or_insert_with(|| {
                let kp = self.p.kproj.min(n.max(1));
                let std = 1.0 / (kp as f32).sqrt();
                let mut rng = crate::rng::Pcg64::new(self.p.seed, 0x11f);
                let e = Mat::gaussian(n, kp, std, &mut rng);
                let f = Mat::gaussian(n, kp, std, &mut rng);
                Arc::new((e, f))
            })
            .clone()
    }
}

impl AttentionBackend for LinformerBackend {
    fn method(&self) -> Method {
        Method::Linformer
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        require_full_spec(Method::Linformer, spec);
        let ef = self.projections(q.rows());
        super::kernels::linformer_attention(q, k, v, &ef.0, &ef.1)
    }
    fn explicit_matrix(&self, _q: &Mat, _k: &Mat, _spec: &AttnSpec) -> Option<Mat> {
        None
    }
    fn flops_model(&self, n: usize, d: usize, _spec: &AttnSpec) -> f64 {
        let (nf, df, kp) = (n as f64, d as f64, self.p.kproj as f64);
        4.0 * nf * kp * df + (4.0 * df + 5.0) * nf * kp
    }
}

// ---------------------------------------------------------------------------
// Low-precision K/V storage
// ---------------------------------------------------------------------------

/// Encode-then-decode a matrix through `prec` row by row — exactly the
/// values a [`RowStore`](crate::lowp::RowStore) decode cache would hand
/// the kernels for the same rows (per-row quantization is a pure
/// function of the row, so batch and decode storage agree bitwise).
fn roundtrip_mat(prec: Precision, m: &Mat) -> Mat {
    match prec {
        Precision::F32 => m.clone(),
        Precision::Bf16 | Precision::F16 => {
            let mut out = m.clone();
            out.map_inplace(|x| prec.roundtrip(x));
            out
        }
        Precision::Int8Kv => {
            let mut out = m.clone();
            let cols = out.cols();
            for row in out.data_mut().chunks_mut(cols.max(1)) {
                let (scale, zero) = quant_params(row);
                for x in row.iter_mut() {
                    *x = dequantize(quantize(*x, scale, zero), scale, zero);
                }
            }
            out
        }
    }
}

/// Storage-precision wrapper applied by [`backend_for`] whenever
/// `params.precision != F32`: the at-rest K/V operands are passed
/// through the configured encoding before the wrapped backend computes,
/// so a batch forward sees exactly the rows a decode cache stores and
/// batch-vs-decode parity survives quantization.  Arithmetic stays f32
/// throughout — only storage narrows.  Under low precision the
/// forward-vs-`explicit_matrix` invariant holds to the precision's
/// documented tolerance (the matrix route reads raw `v`), and training
/// (`forward_train`/`backward`) intentionally bypasses the encoding:
/// precision is a storage/serving knob, not a QAT pass.
struct StoredKvBackend {
    inner: Box<dyn AttentionBackend>,
    prec: Precision,
}

impl AttentionBackend for StoredKvBackend {
    fn method(&self) -> Method {
        self.inner.method()
    }
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, spec: &AttnSpec) -> Mat {
        let k = roundtrip_mat(self.prec, k);
        let v = roundtrip_mat(self.prec, v);
        self.inner.forward(q, &k, &v, spec)
    }
    fn explicit_matrix(&self, q: &Mat, k: &Mat, spec: &AttnSpec) -> Option<Mat> {
        let k = roundtrip_mat(self.prec, k);
        self.inner.explicit_matrix(q, &k, spec)
    }
    fn flops_model(&self, n: usize, d: usize, spec: &AttnSpec) -> f64 {
        self.inner.flops_model(n, d, spec)
    }
    fn begin_decode(&self, d: usize, dv: usize) -> Result<DecodeState, String> {
        self.inner.begin_decode(d, dv)
    }
    fn decode_step(&self, state: &mut DecodeState, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        self.inner.decode_step(state, q, k, v)
    }
    fn forward_train(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
    ) -> Result<(Mat, AttnCache), String> {
        self.inner.forward_train(q, k, v, spec)
    }
    fn backward(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        spec: &AttnSpec,
        cache: &AttnCache,
        d_out: &Mat,
    ) -> Result<AttnGrads, String> {
        self.inner.backward(q, k, v, spec, cache, d_out)
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Construct the backend for a method with explicit parameters.  The
/// kernel-dispatch entry is resolved here, once, from
/// `params.head_dim` (the monomorphized microkernel table); a
/// non-`F32` `params.precision` additionally wraps the backend in the
/// K/V storage-encoding layer.
pub fn backend_for(method: Method, params: BackendParams) -> Box<dyn AttentionBackend> {
    let params = params.resolve_kernel();
    let inner: Box<dyn AttentionBackend> = match method {
        Method::Softmax => Box::new(SoftmaxBackend(params)),
        Method::Lln => Box::new(LlnBackend(params)),
        Method::LlnDiag => Box::new(LlnDiagBackend(params)),
        Method::Elu => Box::new(EluBackend(params)),
        Method::Relu => Box::new(ReluBackend(params)),
        Method::Quadratic => Box::new(QuadraticBackend(params)),
        Method::Performer => Box::new(PerformerBackend::new(params)),
        Method::Nystrom => Box::new(NystromBackend(params)),
        Method::BlockDiag => Box::new(BlockDiagBackend(params)),
        Method::Linformer => Box::new(LinformerBackend::new(params)),
    };
    if params.precision == Precision::F32 {
        // Bitwise escape hatch: no wrapper between callers and the
        // kernels when storage is full-width.
        return inner;
    }
    Box::new(StoredKvBackend { inner, prec: params.precision })
}

/// Construct the backend for a method with default parameters.
pub fn default_backend(method: Method) -> Box<dyn AttentionBackend> {
    backend_for(method, BackendParams::default())
}

/// Every registered backend, in [`Method::ALL`] order.
pub fn all_backends() -> Vec<Box<dyn AttentionBackend>> {
    Method::ALL.iter().map(|&m| default_backend(m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::gaussian_qkv;
    use crate::rng::Pcg64;

    const FULL: AttnSpec = AttnSpec::FULL;

    fn probe(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        gaussian_qkv(n, d, 0.8, 0.8, &mut rng)
    }

    #[test]
    fn registry_covers_every_method_with_matching_names() {
        let backends = all_backends();
        assert_eq!(backends.len(), Method::ALL.len());
        for (bk, m) in backends.iter().zip(Method::ALL) {
            assert_eq!(bk.method(), m);
            assert_eq!(bk.name(), m.name());
        }
    }

    #[test]
    fn unfused_softmax_backend_matches_scalar_reference() {
        // The materialized pipeline (fused = false) stays pinned
        // bitwise to the scalar kernel route: both sides run the same
        // register-blocked microkernels in the same per-row FP order.
        let (q, k, v) = probe(64, 32, 1);
        let params = BackendParams { fused: false, ..Default::default() };
        let fast = backend_for(Method::Softmax, params).forward(&q, &k, &v, &FULL);
        let slow = crate::attention::softmax_attention(&q, &k, &v);
        assert_eq!(fast.data(), slow.data(), "row-partitioned path must be bitwise identical");
    }

    #[test]
    fn fused_softmax_backend_matches_unfused_within_eps() {
        // Default (fused) forward reorders f32 sums but must agree with
        // the materialized pipeline to streaming-softmax tolerance.
        let (q, k, v) = probe(96, 32, 8);
        for tile in [0usize, 16, 40, 200] {
            let fused = backend_for(
                Method::Softmax,
                BackendParams { tile, ..Default::default() },
            )
            .forward(&q, &k, &v, &FULL);
            let unfused = backend_for(
                Method::Softmax,
                BackendParams { fused: false, ..Default::default() },
            )
            .forward(&q, &k, &v, &FULL);
            let err = fused.max_abs_diff(&unfused);
            assert!(err < 1e-5, "tile={tile}: {err}");
        }
    }

    #[test]
    fn fused_quadratic_backend_matches_matrix_route() {
        let (q, k, v) = probe(96, 16, 9);
        let bk = default_backend(Method::Quadratic);
        let p = bk.explicit_matrix(&q, &k, &FULL).unwrap();
        let err = bk.forward(&q, &k, &v, &FULL).max_abs_diff(&p.matmul(&v));
        assert!(err < 1e-4, "fused quadratic vs matrix route: {err}");
    }

    #[test]
    fn lln_backend_matches_scalar_reference() {
        let (q, k, v) = probe(96, 32, 2);
        let params = BackendParams { alpha: 1.4, beta: 1.4, chunk: 17, ..Default::default() };
        let fast = backend_for(Method::Lln, params).forward(&q, &k, &v, &FULL);
        let slow = crate::attention::lln_attention(&q, &k, &v, 1.4, 1.4);
        let err = fast.max_abs_diff(&slow);
        assert!(err < 1e-4, "streamed vs scalar: {err}");
    }

    #[test]
    fn forward_parity_with_explicit_matrix() {
        // The trait's core invariant, spot-checked here (the exhaustive
        // randomized version lives in rust/tests/prop_kernels.rs).
        let (q, k, v) = probe(64, 16, 3);
        for m in [Method::Softmax, Method::Lln, Method::LlnDiag, Method::Elu, Method::BlockDiag] {
            let bk = default_backend(m);
            let p = bk.explicit_matrix(&q, &k, &FULL).unwrap();
            let err = bk.forward(&q, &k, &v, &FULL).max_abs_diff(&p.matmul(&v));
            assert!(err < 1e-3, "{}: forward vs matrix route: {err}", bk.name());
        }
    }

    #[test]
    fn causal_forward_parity_with_explicit_matrix() {
        // The same invariant under the causal and causal+padded masks,
        // for every maskable method with a dense matrix.
        let (q, k, v) = probe(64, 16, 11);
        for spec in [AttnSpec::CAUSAL, AttnSpec::causal_padded(40), AttnSpec::padded(24)] {
            for m in [
                Method::Softmax,
                Method::Lln,
                Method::LlnDiag,
                Method::Elu,
                Method::Relu,
                Method::Quadratic,
                Method::Performer,
                Method::BlockDiag,
            ] {
                let bk = default_backend(m);
                let p = bk.explicit_matrix(&q, &k, &spec).unwrap();
                let err = bk.forward(&q, &k, &v, &spec).max_abs_diff(&p.matmul(&v));
                assert!(err < 1e-3, "{} {spec:?}: forward vs matrix route: {err}", bk.name());
            }
        }
    }

    #[test]
    fn causal_explicit_matrices_have_no_future_mass() {
        let (q, k, _) = probe(64, 16, 12);
        for m in [Method::Softmax, Method::Lln, Method::Quadratic, Method::BlockDiag] {
            let p = default_backend(m).explicit_matrix(&q, &k, &AttnSpec::CAUSAL).unwrap();
            for i in 0..64 {
                for j in (i + 1)..64 {
                    assert_eq!(p.get(i, j), 0.0, "{m:?}: future mass at ({i},{j})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "cannot honor causal")]
    fn nystrom_rejects_causal_spec() {
        let (q, k, v) = probe(32, 16, 13);
        default_backend(Method::Nystrom).forward(&q, &k, &v, &AttnSpec::CAUSAL);
    }

    #[test]
    #[should_panic(expected = "cannot honor causal")]
    fn linformer_rejects_padded_spec() {
        let (q, k, v) = probe(32, 16, 14);
        default_backend(Method::Linformer).forward(&q, &k, &v, &AttnSpec::padded(16));
    }

    #[test]
    fn explicit_matrices_are_stochastic() {
        let (q, k, _) = probe(64, 32, 4);
        for bk in all_backends() {
            if let Some(p) = bk.explicit_matrix(&q, &k, &FULL) {
                assert!(p.is_stochastic(1e-3), "{} matrix not stochastic", bk.name());
            }
        }
    }

    #[test]
    fn lln_diag_degrades_to_lln_when_tile_does_not_divide() {
        // Regression: analysis sweeps call attention_matrix(LlnDiag)
        // with probe lengths that are not multiples of the tile (e.g.
        // fig-2 at n=96 with block=64) — that must not panic, and must
        // return the long-range LLN matrix as the old dispatch did.
        let (q, k, v) = probe(96, 16, 7);
        let p = crate::attention::attention_matrix(Method::LlnDiag, &q, &k, 1.3, 1.3);
        let lln_only = crate::attention::lln_attention_matrix(&q, &k, 1.3, 1.3);
        assert!(p.max_abs_diff(&lln_only) < 1e-6);
        assert!(p.is_stochastic(1e-3));
        // forward must degrade the same way (no panic, parity intact).
        let bk = backend_for(Method::LlnDiag, BackendParams { alpha: 1.3, beta: 1.3, ..Default::default() });
        let out = bk.forward(&q, &k, &v, &FULL);
        let err = out.max_abs_diff(&p.matmul(&v));
        assert!(err < 1e-3, "degraded forward vs matrix route: {err}");
    }

    #[test]
    fn blockdiag_decode_cache_is_bounded_by_the_tile_window() {
        // The decode session must match the causal batch forward AND
        // hold at most one diagonal tile of K/V rows at any time
        // (completed tiles are never read again).
        let (q, k, v) = probe(96, 16, 21);
        let bk = backend_for(Method::BlockDiag, BackendParams { block: 16, ..Default::default() });
        let full = bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
        let mut st = bk.begin_decode(16, 16).unwrap();
        let mut max_bytes = 0usize;
        for i in 0..96 {
            let row = bk.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
            let err =
                row.iter().zip(full.row(i)).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(err < 1e-4, "step {i}: {err}");
            max_bytes = max_bytes.max(st.state_bytes());
        }
        assert_eq!(st.len(), 96, "eviction must not rewind the session length");
        assert!(max_bytes <= 2 * 16 * 16 * 4, "tile window leaked: {max_bytes} bytes");
    }

    #[test]
    fn implicit_methods_report_no_matrix() {
        let (q, k, _) = probe(32, 16, 5);
        for m in [Method::Nystrom, Method::Linformer] {
            assert!(default_backend(m).explicit_matrix(&q, &k, &FULL).is_none());
        }
    }

    #[test]
    fn flops_model_separates_quadratic_from_linear() {
        let d = 64;
        for bk in all_backends() {
            let f1 = bk.flops_model(1024, d, &FULL);
            let f4 = bk.flops_model(4096, d, &FULL);
            assert!(f1 > 0.0 && f4 > f1, "{}", bk.name());
            let growth = f4 / f1;
            if bk.method().is_linear() {
                assert!(growth < 6.0, "{}: linear method grew {growth}x", bk.name());
            } else {
                assert!(growth > 10.0, "{}: quadratic method grew {growth}x", bk.name());
            }
        }
    }

    #[test]
    fn flops_model_pinned_points_under_specs() {
        let (n, d) = (1024usize, 64usize);
        let (nf, df) = (n as f64, d as f64);
        let sm = default_backend(Method::Softmax);
        // Dense: (4d+5)·n²;  causal: (4d+5)·n(n+1)/2 — the halving.
        assert_eq!(sm.flops_model(n, d, &FULL), (4.0 * df + 5.0) * nf * nf);
        assert_eq!(
            sm.flops_model(n, d, &AttnSpec::CAUSAL),
            (4.0 * df + 5.0) * nf * (nf + 1.0) / 2.0
        );
        let ratio = sm.flops_model(n, d, &AttnSpec::CAUSAL) / sm.flops_model(n, d, &FULL);
        assert!((ratio - 0.5).abs() < 1e-3, "causal must ~halve softmax flops: {ratio}");
        // Padded: (4d+5)·n·kl.
        assert_eq!(
            sm.flops_model(n, d, &AttnSpec::padded(256)),
            (4.0 * df + 5.0) * nf * 256.0
        );
        // Linear class: causal costs the same (the O(N) story), padding
        // drops the dead key rows.
        let lln = default_backend(Method::Lln);
        assert_eq!(lln.flops_model(n, d, &FULL), 2.0 * nf * (2.0 * df * df + 3.0 * df));
        assert_eq!(lln.flops_model(n, d, &AttnSpec::CAUSAL), lln.flops_model(n, d, &FULL));
        assert_eq!(
            lln.flops_model(n, d, &AttnSpec::padded(256)),
            (nf + 256.0) * (2.0 * df * df + 3.0 * df)
        );
        // BlockDiag: n·b dense pairs, per-tile triangles under causal.
        let bd = default_backend(Method::BlockDiag);
        assert_eq!(bd.flops_model(n, d, &FULL), (4.0 * df + 5.0) * nf * 64.0);
        assert_eq!(
            bd.flops_model(n, d, &AttnSpec::CAUSAL),
            (4.0 * df + 5.0) * (n / 64) as f64 * (64.0 * 65.0 / 2.0)
        );
    }

    #[test]
    fn forward_train_matches_inference_forward() {
        let (q, k, v) = probe(48, 16, 30);
        for spec in [FULL, AttnSpec::CAUSAL, AttnSpec::causal_padded(20)] {
            for m in [
                Method::Softmax,
                Method::Lln,
                Method::LlnDiag,
                Method::Elu,
                Method::Relu,
                Method::Quadratic,
                Method::Performer,
                Method::BlockDiag,
            ] {
                // block = 16 divides n = 48 so the tile-structured
                // methods run their full hybrid/tiled training path.
                let bk = backend_for(
                    m,
                    BackendParams { alpha: 1.2, beta: 1.2, block: 16, ..Default::default() },
                );
                let (out, _cache) = bk.forward_train(&q, &k, &v, &spec).unwrap();
                let fwd = bk.forward(&q, &k, &v, &spec);
                let err = out.max_abs_diff(&fwd);
                assert!(err < 1e-4, "{m:?} {spec:?}: train-forward vs forward {err}");
            }
        }
    }

    #[test]
    fn backward_produces_shaped_finite_grads_and_lln_alpha_flows() {
        let (q, k, v) = probe(32, 12, 31);
        let mut rng = Pcg64::seed(32);
        let d_out = Mat::gaussian(32, 12, 1.0, &mut rng);
        for m in [
            Method::Softmax,
            Method::Lln,
            Method::LlnDiag,
            Method::Elu,
            Method::Relu,
            Method::Quadratic,
            Method::Performer,
            Method::BlockDiag,
        ] {
            let bk = backend_for(
                m,
                BackendParams { alpha: 1.1, beta: 0.9, block: 16, ..Default::default() },
            );
            let (_, cache) = bk.forward_train(&q, &k, &v, &AttnSpec::CAUSAL).unwrap();
            let g = bk.backward(&q, &k, &v, &AttnSpec::CAUSAL, &cache, &d_out).unwrap();
            assert_eq!(g.dq.shape(), q.shape(), "{m:?}");
            assert_eq!(g.dk.shape(), k.shape(), "{m:?}");
            assert_eq!(g.dv.shape(), v.shape(), "{m:?}");
            for mat in [&g.dq, &g.dk, &g.dv] {
                assert!(mat.data().iter().all(|x| x.is_finite()), "{m:?}");
            }
            if matches!(m, Method::Lln | Method::LlnDiag) {
                assert!(g.dalpha != 0.0 && g.dbeta != 0.0, "lln exponents must receive grads");
            } else {
                assert_eq!((g.dalpha, g.dbeta), (0.0, 0.0), "{m:?}");
            }
        }
    }

    #[test]
    fn untrainable_methods_refuse_forward_train_as_err() {
        let (q, k, v) = probe(32, 16, 33);
        for m in [Method::Nystrom, Method::Linformer] {
            let err = default_backend(m).forward_train(&q, &k, &v, &FULL).unwrap_err();
            assert!(err.contains("backward"), "{m:?}: {err}");
        }
    }

    #[test]
    fn blockdiag_train_requires_dividing_tile_and_lln_diag_degrades() {
        // BlockDiag: a tile that does not divide N is a clean Err (the
        // inference kernel would assert), never a panic.
        let (q, k, v) = probe(48, 16, 35);
        let bd = default_backend(Method::BlockDiag); // block = 64, 48 % 64 != 0
        let err = bd.forward_train(&q, &k, &v, &FULL).unwrap_err();
        assert!(err.contains("divide"), "{err}");
        // LLN+Diag under the same shape degrades to the pure-LLN path
        // (mirroring `forward`), so training still proceeds: its grads
        // match the plain LLN backend's exactly.
        let mut rng = Pcg64::seed(36);
        let d_out = Mat::gaussian(48, 16, 1.0, &mut rng);
        let params = BackendParams { alpha: 1.1, beta: 0.9, ..Default::default() };
        let hybrid = backend_for(Method::LlnDiag, params);
        let plain = backend_for(Method::Lln, params);
        let (out_h, cache_h) = hybrid.forward_train(&q, &k, &v, &FULL).unwrap();
        let (out_p, cache_p) = plain.forward_train(&q, &k, &v, &FULL).unwrap();
        assert_eq!(out_h.data(), out_p.data(), "degraded hybrid must be pure LLN");
        let gh = hybrid.backward(&q, &k, &v, &FULL, &cache_h, &d_out).unwrap();
        let gp = plain.backward(&q, &k, &v, &FULL, &cache_p, &d_out).unwrap();
        assert_eq!(gh.dq.data(), gp.dq.data());
        assert_eq!(gh.dk.data(), gp.dk.data());
        assert_eq!(gh.dv.data(), gp.dv.data());
        assert_eq!((gh.dalpha, gh.dbeta), (gp.dalpha, gp.dbeta));
    }

    #[test]
    fn backward_rejects_mismatched_cache() {
        let (q, k, v) = probe(16, 8, 34);
        let sm = default_backend(Method::Softmax);
        let lln = default_backend(Method::Lln);
        let (_, lln_cache) = lln.forward_train(&q, &k, &v, &FULL).unwrap();
        let err = sm.backward(&q, &k, &v, &FULL, &lln_cache, &v).unwrap_err();
        assert!(err.contains("different method class"), "{err}");
    }

    #[test]
    fn pinned_kernel_dispatch_is_bitwise_identical_to_auto() {
        // head_dim = 32 pins the monomorphized D32 instance, head_dim =
        // 77 pins the generic fallback; both must be bitwise identical
        // to the default per-call Auto lookup (the specialized kernels
        // are exact statement-for-statement copies of the generic loop).
        let (q, k, v) = probe(48, 32, 40);
        for m in [Method::Softmax, Method::Lln, Method::Quadratic, Method::BlockDiag] {
            let auto = backend_for(m, BackendParams::default());
            let base = auto.forward(&q, &k, &v, &AttnSpec::CAUSAL);
            for head_dim in [32usize, 77] {
                let pinned = backend_for(m, BackendParams { head_dim, ..Default::default() });
                let out = pinned.forward(&q, &k, &v, &AttnSpec::CAUSAL);
                assert_eq!(out.data(), base.data(), "{m:?} head_dim={head_dim}: forward drifted");
                let mut sa = auto.begin_decode(32, 32).unwrap();
                let mut sp = pinned.begin_decode(32, 32).unwrap();
                for i in 0..8 {
                    let ra = auto.decode_step(&mut sa, q.row(i), k.row(i), v.row(i));
                    let rp = pinned.decode_step(&mut sp, q.row(i), k.row(i), v.row(i));
                    assert_eq!(ra, rp, "{m:?} head_dim={head_dim} step {i}: decode drifted");
                }
            }
        }
    }

    #[test]
    fn f32_precision_is_a_bitwise_escape_hatch() {
        // precision = f32 must construct the identical unwrapped
        // pipeline — not an f32-encoded copy of the operands.
        let (q, k, v) = probe(48, 32, 41);
        let plain = default_backend(Method::Softmax).forward(&q, &k, &v, &FULL);
        let explicit = backend_for(
            Method::Softmax,
            BackendParams { precision: Precision::F32, ..Default::default() },
        )
        .forward(&q, &k, &v, &FULL);
        assert_eq!(plain.data(), explicit.data());
    }

    #[test]
    fn low_precision_storage_bounds_forward_error_and_shrinks_decode_state() {
        let (q, k, v) = probe(48, 32, 42);
        let exact = default_backend(Method::Softmax).forward(&q, &k, &v, &FULL);
        // Loose smoke bounds; the documented per-format tolerances are
        // pinned on the raw encodings in lowp.rs and in the property
        // suite — this checks they survive the full attention pipeline.
        for (prec, tol) in
            [(Precision::Bf16, 0.05f32), (Precision::F16, 0.01), (Precision::Int8Kv, 0.2)]
        {
            let bk = backend_for(
                Method::Softmax,
                BackendParams { precision: prec, ..Default::default() },
            );
            let err = bk.forward(&q, &k, &v, &FULL).max_abs_diff(&exact);
            assert!(err > 0.0, "{prec:?}: storage encoding must actually narrow");
            assert!(err < tol, "{prec:?}: forward drifted {err} (tol {tol})");
        }
        // Decode caches store the encoded rows: int8-kv must cut the
        // per-session resident bytes by >= 2x vs f32 (ISSUE acceptance).
        let f32_bytes = {
            let bk = default_backend(Method::Softmax);
            let mut st = bk.begin_decode(32, 32).unwrap();
            for i in 0..16 {
                bk.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
            }
            st.state_bytes()
        };
        let int8_bk = backend_for(
            Method::Softmax,
            BackendParams { precision: Precision::Int8Kv, ..Default::default() },
        );
        let mut st = int8_bk.begin_decode(32, 32).unwrap();
        for i in 0..16 {
            int8_bk.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
        }
        assert!(
            st.state_bytes() * 2 <= f32_bytes,
            "int8-kv decode state must shrink >= 2x: {} vs {f32_bytes}",
            st.state_bytes()
        );
    }

    #[test]
    fn int8_decode_replay_matches_int8_batch_forward() {
        // The design's consistency claim: per-row quantization is a
        // pure function of the row, so the rows the decode cache stores
        // are bitwise the rows the batch forward roundtrips — replaying
        // a causal forward token-by-token stays within the usual
        // streaming-softmax tolerance even at int8 storage.
        let (q, k, v) = probe(32, 32, 43);
        let bk = backend_for(
            Method::Softmax,
            BackendParams { precision: Precision::Int8Kv, ..Default::default() },
        );
        let full = bk.forward(&q, &k, &v, &AttnSpec::CAUSAL);
        let mut st = bk.begin_decode(32, 32).unwrap();
        for i in 0..32 {
            let row = bk.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
            let err =
                row.iter().zip(full.row(i)).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(err < 1e-3, "step {i}: quantized decode vs batch drifted {err}");
        }
    }

    #[test]
    fn linformer_and_nystrom_forward_are_finite() {
        let (q, k, v) = probe(64, 16, 6);
        for m in [Method::Nystrom, Method::Linformer] {
            let out = default_backend(m).forward(&q, &k, &v, &FULL);
            assert_eq!(out.shape(), (64, 16));
            assert!(out.data().iter().all(|x| x.is_finite()), "{m:?}");
        }
    }
}
