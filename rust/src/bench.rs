//! Micro/macro benchmark harness (criterion substitute).
//!
//! Warmup, timed iterations with per-iteration samples, mean / p50 / p95
//! and throughput reporting.  The `benches/*.rs` targets (built with
//! `harness = false`) compose these into the paper's tables.

use std::time::Instant;

/// One benchmark's collected samples (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    /// Optional work units per iteration (tokens, requests...) for throughput.
    pub units_per_iter: f64,
}

impl BenchResult {
    /// Mean seconds per iteration; 0.0 (never NaN) on an empty sample
    /// set (an interrupted or zero-budget run).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        crate::stats::percentile(&self.samples, q)
    }
    pub fn std(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / self.samples.len() as f64).sqrt()
    }
    pub fn throughput(&self) -> f64 {
        let m = self.mean();
        if m <= 0.0 {
            return 0.0;
        }
        self.units_per_iter / m
    }

    pub fn report_line(&self) -> String {
        let m = self.mean();
        let unit = if m < 1e-3 {
            format!("{:8.1} us", m * 1e6)
        } else if m < 1.0 {
            format!("{:8.2} ms", m * 1e3)
        } else {
            format!("{:8.3} s ", m)
        };
        let tp = if self.units_per_iter > 0.0 {
            format!("  {:10.0} units/s", self.throughput())
        } else {
            String::new()
        };
        format!(
            "{:<40} {}  p50 {:8.2} ms  p95 {:8.2} ms  (n={}){}",
            self.name,
            unit,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.samples.len(),
            tp
        )
    }
}

/// Benchmark runner with time-budgeted sampling.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub time_budget_secs: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 2, min_iters: 5, max_iters: 200, time_budget_secs: 3.0, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Self { warmup_iters: 1, min_iters: 3, max_iters: 30, time_budget_secs: 1.0, results: Vec::new() }
    }

    /// Time `f`, preventing dead-code elimination via the returned value.
    /// Always takes at least one sample — a zero `min_iters`/budget
    /// configuration (or an interrupted run's leftovers) must never
    /// produce an empty result that panics downstream stats.
    pub fn run<T>(&mut self, name: &str, units_per_iter: f64, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let budget_start = Instant::now();
        while samples.len() < self.min_iters.max(1)
            || (samples.len() < self.max_iters
                && budget_start.elapsed().as_secs_f64() < self.time_budget_secs)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let idx = self.results.len();
        self.results.push(BenchResult { name: name.to_string(), samples, units_per_iter });
        let r = &self.results[idx];
        println!("{}", r.report_line());
        r
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Opaque value sink (std::hint::black_box wrapper kept local so the
/// harness compiles on stable if the hint ever changes).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Bench one [`AttentionBackend`](crate::attention::AttentionBackend)
/// forward at (n, d) on seeded Gaussian probes under an
/// [`AttnSpec`](crate::attention::AttnSpec); returns the mean seconds
/// per forward.  The shared entry point for `kernel_micro` and
/// `attention_scaling`, so every bench target times methods through the
/// same registry dispatch the serving path uses.
pub fn run_attention_backend(
    b: &mut Bench,
    backend: &dyn crate::attention::AttentionBackend,
    n: usize,
    d: usize,
    seed: u64,
    spec: &crate::attention::AttnSpec,
) -> f64 {
    let mut rng = crate::rng::Pcg64::seed(seed);
    let q = crate::tensor::Mat::gaussian(n, d, 1.0, &mut rng);
    let k = crate::tensor::Mat::gaussian(n, d, 1.0, &mut rng);
    let v = crate::tensor::Mat::gaussian(n, d, 1.0, &mut rng);
    let tag = if spec.causal { " causal" } else { "" };
    let name = format!("backend {}{tag} n={n}", backend.name());
    b.run(&name, n as f64, || backend.forward(&q, &k, &v, spec)).mean()
}

// ---------------------------------------------------------------------------
// Kernel perf trajectory (BENCH_kernels.json)
// ---------------------------------------------------------------------------

/// One timed kernel entry of the JSON trajectory report.
#[derive(Clone, Debug)]
pub struct KernelRecord {
    pub name: &'static str,
    pub n: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub iters: usize,
}

/// One decode-state footprint entry of the report: a real
/// [`KvCache`](crate::attention::KvCache) fed `tokens` rows at a
/// storage precision, reporting its own `state_bytes()` — the
/// `[compute] precision` savings measured, not modeled.
#[derive(Clone, Debug)]
pub struct MemoryRecord {
    pub name: &'static str,
    pub tokens: usize,
    pub bytes: usize,
}

/// The `lln bench --json` / `kernel_micro -- --json` report: per-method
/// ns/op at each probed sequence length plus derived speedups — the
/// cross-PR perf record CI uploads as the `BENCH_kernels.json`
/// artifact.
pub struct KernelReport {
    pub d: usize,
    pub threads: usize,
    pub records: Vec<KernelRecord>,
    /// Decode-state bytes per storage precision (`kv_state_bytes_*`).
    pub memory: Vec<MemoryRecord>,
}

/// (fast, slow) kernel pairs whose ratio the report derives whenever
/// both were measured at the same n.  `softmax_fused` vs
/// `softmax_pipeline_pr1` at n=4096 is the headline acceptance number;
/// `softmax_fused_causal` vs `softmax_masked_dense_causal` is the
/// causal-PR acceptance (fused causal must be ≤ ~0.6× the masked dense
/// route's time at n=4096, i.e. speedup ≥ ~1.67×).
const SPEEDUP_PAIRS: &[(&str, &str)] = &[
    ("softmax_fused", "softmax_pipeline_pr1"),
    ("softmax_fused", "softmax_pipeline_blocked"),
    ("softmax_fused_causal", "softmax_masked_dense_causal"),
    ("softmax_fused_causal", "softmax_fused"),
    ("matmul_t_blocked", "matmul_t_pr1"),
    // Amortized decode-vs-prefill: the `*_decode_step` rows are ns per
    // *token* while the causal rows are ns per *prefill*, so the ratio
    // is exactly what a decode session saves over the naive
    // re-run-the-whole-causal-forward-per-new-token serving loop.
    ("softmax_decode_step", "softmax_fused_causal"),
    ("lln_decode_step", "lln_causal"),
    // Backward-vs-forward cost ratios: the flash-style recompute
    // backward classically lands at ~2-2.5x its forward.
    ("softmax_fused", "softmax_fused_bwd"),
    ("lln_streamed", "lln_bwd"),
    // Pooled-vs-serial training backward: the compute-pool span/chunk
    // parallelization of the same kernels (≈ thread count on an idle
    // multi-core box, ≈ 1.0x on a single-core runner).
    ("softmax_fused_bwd_par", "softmax_fused_bwd"),
    ("lln_bwd_par", "lln_bwd"),
    // Small-matmul fallback: outputs under PAR_MIN_ELEMS skip the pool,
    // so par_matmul at tiny n must cost the same as plain matmul (≈
    // 1.0x) — the row pair that pins the threshold.
    ("par_matmul_small", "matmul_small"),
    // Monomorphized-vs-generic microkernel pairs: the same inner loops
    // with the head dim a compile-time const (D ∈ {32, 64, 128}) vs a
    // runtime value.  These are the rows the CI baseline gate watches
    // (`lln bench --baseline BENCH_kernels.json`).
    ("matmul_t_spec", "matmul_t_gen"),
    ("softmax_decode_spec", "softmax_decode_gen"),
    ("lln_prefix_spec", "lln_prefix_gen"),
    // Multi-head backward vs single-head at the same n: 4 bands of d/4
    // do ~d/4-width dots over the same n² pairs, so ≈ 1.0x is healthy.
    ("softmax_fused_bwd_heads", "softmax_fused_bwd"),
    // Data-parallel native train step at 2/4 shards vs 1: the gradient
    // all-reduce is fixed-order, so these quote pure pool scaling on a
    // bitwise-identical step (≈ 1.0x on a single-core runner).
    ("train_step_dp2", "train_step_dp1"),
    ("train_step_dp4", "train_step_dp1"),
];

/// The PR-1 scalar-dot baseline is only timed up to this n — it is the
/// slow thing being replaced, and above 4k it also re-materializes the
/// n×n matrix the fused path exists to avoid.
pub const PR1_BASELINE_MAX_N: usize = 4096;

impl KernelReport {
    pub fn mean_ns(&self, name: &str, n: usize) -> Option<f64> {
        self.records.iter().find(|r| r.name == name && r.n == n).map(|r| r.mean_ns)
    }

    /// slow/fast time ratio, when both kernels were measured at `n`.
    pub fn speedup(&self, fast: &str, slow: &str, n: usize) -> Option<f64> {
        let f = self.mean_ns(fast, n)?;
        let s = self.mean_ns(slow, n)?;
        if f > 0.0 {
            Some(s / f)
        } else {
            None
        }
    }

    fn sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self.records.iter().map(|r| r.n).collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }

    /// Every derivable (fast, slow, n, ratio) speedup line.
    pub fn speedups(&self) -> Vec<(&'static str, &'static str, usize, f64)> {
        let mut out = Vec::new();
        for &(fast, slow) in SPEEDUP_PAIRS {
            for n in self.sizes() {
                if let Some(sp) = self.speedup(fast, slow, n) {
                    out.push((fast, slow, n, sp));
                }
            }
        }
        out
    }

    /// Hand-rolled JSON (the image vendors no serde); schema is flat on
    /// purpose so the trajectory stays diffable across PRs.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str("  \"bench\": \"kernels\",\n");
        s.push_str(&format!("  \"d\": {},\n", self.d));
        s.push_str(&format!("  \"threads\": {},\n", self.threads));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            let sep = if i + 1 == self.records.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"n\": {}, \"ns_per_op\": {:.0}, \"p50_ns\": {:.0}, \"iters\": {}}}{}\n",
                r.name, r.n, r.mean_ns, r.p50_ns, r.iters, sep
            ));
        }
        s.push_str("  ],\n");
        if !self.memory.is_empty() {
            s.push_str("  \"memory\": [\n");
            for (i, m) in self.memory.iter().enumerate() {
                let sep = if i + 1 == self.memory.len() { "" } else { "," };
                s.push_str(&format!(
                    "    {{\"name\": \"{}\", \"tokens\": {}, \"bytes\": {}}}{}\n",
                    m.name, m.tokens, m.bytes, sep
                ));
            }
            s.push_str("  ],\n");
        }
        // Sparse reports (a capped method row, an interrupted run)
        // simply have fewer — possibly zero — derivable pairs; absent
        // pairs are skipped, never unwrapped.
        let lines: Vec<String> = self
            .speedups()
            .iter()
            .map(|(fast, slow, n, sp)| format!("    \"{fast}_vs_{slow}_n{n}\": {sp:.2}"))
            .collect();
        if lines.is_empty() {
            s.push_str("  \"speedups\": {}\n}\n");
        } else {
            s.push_str("  \"speedups\": {\n");
            s.push_str(&lines.join(",\n"));
            s.push_str("\n  }\n}\n");
        }
        s
    }

    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json())
    }
}

/// Compare a fresh report against a committed `BENCH_kernels.json` and
/// list every *specialized* kernel row (`*_spec`) that regressed by
/// more than `threshold` (fractional: 0.25 = 25% slower) — the CI perf
/// gate for the monomorphized microkernels.  Only `_spec` rows gate:
/// the generic rows exist as denominators, and the macro rows are too
/// machine-noisy to block merges on.  Baseline rows with zero ns/op
/// (the honest "not yet measured" bootstrap committed before a runner
/// first populates the file) and (name, n) points absent from either
/// side are skipped, never failed.  `Err` only on unparsable baseline
/// JSON.
pub fn spec_regressions(
    report: &KernelReport,
    baseline_json: &str,
    threshold: f64,
) -> Result<Vec<String>, String> {
    let base = crate::util::json::Json::parse(baseline_json)
        .map_err(|e| format!("unparsable baseline JSON: {e}"))?;
    let rows = base
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| "baseline JSON has no \"results\" array".to_string())?;
    let mut out = Vec::new();
    for row in rows {
        let name = match row.get("name").and_then(|v| v.as_str()) {
            Some(n) if n.ends_with("_spec") => n,
            _ => continue,
        };
        let (n, base_ns) = match (
            row.get("n").and_then(|v| v.as_usize()),
            row.get("ns_per_op").and_then(|v| v.as_f64()),
        ) {
            (Some(n), Some(ns)) if ns > 0.0 => (n, ns),
            _ => continue, // un-baselined bootstrap row
        };
        if let Some(new_ns) = report.mean_ns(name, n) {
            if new_ns > base_ns * (1.0 + threshold) {
                out.push(format!(
                    "{name} n={n}: {new_ns:.0} ns/op vs baseline {base_ns:.0} ns/op \
                     (+{:.0}%, gate {:.0}%)",
                    (new_ns / base_ns - 1.0) * 100.0,
                    threshold * 100.0
                ));
            }
        }
    }
    Ok(out)
}

/// Run the kernel perf trajectory suite: at each n, the PR-1 scalar-dot
/// pipeline baseline (up to [`PR1_BASELINE_MAX_N`]), the
/// register-blocked materialized pipeline, the fused O(n·tile)
/// kernels, and the streamed linear-class forwards.  Shared by the
/// `lln bench` subcommand and the `kernel_micro` bench target so both
/// emit the same BENCH_kernels.json schema.
pub fn run_kernel_bench(
    b: &mut Bench,
    sizes: &[usize],
    d: usize,
    params: crate::attention::BackendParams,
) -> KernelReport {
    use crate::attention::{backend_for, AttnSpec, BackendParams, Method};
    use crate::tensor::Mat;

    const FULL: AttnSpec = AttnSpec::FULL;
    const CAUSAL: AttnSpec = AttnSpec::CAUSAL;
    let threads = crate::tensor::resolve_threads(params.threads);
    // Warm the persistent pool before any timed row so the first
    // pooled kernel never pays worker spawn/first-touch inside its
    // sample window (the CI smoke invokes this path once up front).
    crate::util::compute_pool::scope_rows(threads.max(2) * 8, threads.max(2), |_, _| {});
    let mut records: Vec<KernelRecord> = Vec::new();
    let push = |records: &mut Vec<KernelRecord>, name: &'static str, n: usize, r: &BenchResult| {
        records.push(KernelRecord {
            name,
            n,
            mean_ns: r.mean() * 1e9,
            p50_ns: r.percentile(50.0) * 1e9,
            iters: r.samples.len(),
        });
    };

    for &n in sizes {
        let mut rng = crate::rng::Pcg64::seed(0x5EED ^ n as u64);
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let v = Mat::gaussian(n, d, 1.0, &mut rng);
        let scale = 1.0 / (d as f32).sqrt();
        // Monomorphized-vs-generic microkernel pinning for the `_spec`
        // / `_gen` row pairs.  At a d with no specialized instance,
        // for_dim resolves to Generic and each pair reads ~1.0x — the
        // rows stay comparable across configurations.
        let kern_spec = crate::tensor::KernelDispatch::for_dim(d);
        let kern_gen = crate::tensor::KernelDispatch::Generic;

        if n <= PR1_BASELINE_MAX_N {
            // The PR-1 pipeline this PR replaces: scalar-dot scores +
            // row softmax + value matmul, all materializing n×n.
            let r = b
                .run(&format!("softmax_pipeline_pr1 n={n}"), 1.0, || {
                    let mut s = q.par_matmul_t_ref(&k, params.threads);
                    s.map_inplace(|x| x * scale);
                    s.par_softmax_rows(params.threads);
                    s.par_matmul(&v, params.threads)
                })
                .clone();
            push(&mut records, "softmax_pipeline_pr1", n, &r);

            let r = b
                .run(&format!("matmul_t_pr1 n={n}"), 1.0, || q.par_matmul_t_ref(&k, params.threads))
                .clone();
            push(&mut records, "matmul_t_pr1", n, &r);

            let r = b
                .run(&format!("matmul_t_blocked n={n}"), 1.0, || q.par_matmul_t(&k, params.threads))
                .clone();
            push(&mut records, "matmul_t_blocked", n, &r);

            // The same register-blocked q·kᵀ with the head dim a
            // compile-time const vs a runtime value.
            for (name, kern) in [("matmul_t_spec", kern_spec), ("matmul_t_gen", kern_gen)] {
                let mut out = vec![0.0f32; n * n];
                let r = b
                    .run(&format!("{name} n={n}"), 1.0, || {
                        kern.matmul_t_block(q.data(), k.data(), &mut out, n, d, n);
                        out[0]
                    })
                    .clone();
                push(&mut records, name, n, &r);
            }

            // The masked *dense* causal route (materialize all n×n
            // scores in parallel, mask + softmax, value matmul — the
            // unfused backend path) — the baseline the fused causal
            // kernel must beat.  Capped like the PR-1 pipeline: it
            // re-materializes the n×n matrix the fused path avoids.
            let dense_causal =
                backend_for(Method::Softmax, BackendParams { fused: false, ..params });
            let r = b
                .run(&format!("softmax_masked_dense_causal n={n}"), 1.0, || {
                    dense_causal.forward(&q, &k, &v, &CAUSAL)
                })
                .clone();
            push(&mut records, "softmax_masked_dense_causal", n, &r);
        }

        let unfused = backend_for(Method::Softmax, BackendParams { fused: false, ..params });
        let r = b
            .run(&format!("softmax_pipeline_blocked n={n}"), 1.0, || {
                unfused.forward(&q, &k, &v, &FULL)
            })
            .clone();
        push(&mut records, "softmax_pipeline_blocked", n, &r);

        let fused = backend_for(Method::Softmax, params);
        let r = b
            .run(&format!("softmax_fused n={n}"), 1.0, || fused.forward(&q, &k, &v, &FULL))
            .clone();
        push(&mut records, "softmax_fused", n, &r);

        // Fused causal streaming softmax: prefix tiles only (~half the
        // score work of the full fused kernel).
        let r = b
            .run(&format!("softmax_fused_causal n={n}"), 1.0, || {
                fused.forward(&q, &k, &v, &CAUSAL)
            })
            .clone();
        push(&mut records, "softmax_fused_causal", n, &r);

        let quad = backend_for(Method::Quadratic, params);
        let r = b
            .run(&format!("quadratic_fused n={n}"), 1.0, || quad.forward(&q, &k, &v, &FULL))
            .clone();
        push(&mut records, "quadratic_fused", n, &r);

        let lln = backend_for(Method::Lln, BackendParams { alpha: 2.2, beta: 2.2, ..params });
        let r = b
            .run(&format!("lln_streamed n={n}"), 1.0, || lln.forward(&q, &k, &v, &FULL))
            .clone();
        push(&mut records, "lln_streamed", n, &r);

        // Causal O(N) prefix-state LLN: the decoder-side linear path.
        let r = b
            .run(&format!("lln_causal n={n}"), 1.0, || lln.forward(&q, &k, &v, &CAUSAL))
            .clone();
        push(&mut records, "lln_causal", n, &r);

        // Decode-session rows, recorded as amortized ns per *token*
        // (one iteration steps a fresh session across all n tokens).
        // The softmax KV-cache step pays O(t·d) at prefix t, so its
        // per-token cost grows ~linearly with n (capped like the other
        // quadratic baselines); the linear prefix-state step is O(d²)
        // flat in n — the O(1)/token decode story made measurable.
        let push_per_token =
            |records: &mut Vec<KernelRecord>, name: &'static str, n: usize, r: &BenchResult| {
                records.push(KernelRecord {
                    name,
                    n,
                    mean_ns: r.mean() * 1e9 / n as f64,
                    p50_ns: r.percentile(50.0) * 1e9 / n as f64,
                    iters: r.samples.len(),
                });
            };
        if n <= PR1_BASELINE_MAX_N {
            let r = b
                .run(&format!("softmax_decode_step n={n} (x{n} tokens)"), n as f64, || {
                    let mut st = fused.begin_decode(d, d).expect("softmax decode session");
                    let mut last = Vec::new();
                    for i in 0..n {
                        last = fused.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
                    }
                    last
                })
                .clone();
            push_per_token(&mut records, "softmax_decode_step", n, &r);
        }
        let r = b
            .run(&format!("lln_decode_step n={n} (x{n} tokens)"), n as f64, || {
                let mut st = lln.begin_decode(d, d).expect("lln decode session");
                let mut last = Vec::new();
                for i in 0..n {
                    last = lln.decode_step(&mut st, q.row(i), k.row(i), v.row(i));
                }
                last
            })
            .clone();
        push_per_token(&mut records, "lln_decode_step", n, &r);

        // Monomorphized-vs-generic pinned pairs on the two serving hot
        // paths: one softmax decode step over an n-token KV cache (the
        // per-token microkernel the dispatch table exists for), and the
        // causal O(N) prefix-state recurrence whose per-row state folds
        // monomorphize on dv.
        for (name, kern) in
            [("softmax_decode_spec", kern_spec), ("softmax_decode_gen", kern_gen)]
        {
            let r = b
                .run(&format!("{name} n={n}"), 1.0, || {
                    crate::attention::fused_softmax_decode_step_dispatch(
                        q.row(0),
                        k.data(),
                        v.data(),
                        n,
                        d,
                        d,
                        scale,
                        params.tile,
                        kern,
                    )
                })
                .clone();
            push(&mut records, name, n, &r);
        }
        {
            let pq = crate::attention::lln_features(&q, 2.2);
            let pk = crate::attention::lln_features(&k, 2.2);
            for (name, kern) in [("lln_prefix_spec", kern_spec), ("lln_prefix_gen", kern_gen)] {
                let r = b
                    .run(&format!("{name} n={n}"), 1.0, || {
                        crate::attention::linear_attention_causal_dispatch(
                            &pq,
                            &pk,
                            &v,
                            None,
                            params.chunk,
                            params.threads,
                            kern,
                        )
                    })
                    .clone();
                push(&mut records, name, n, &r);
            }
        }

        let diag = backend_for(Method::LlnDiag, BackendParams { alpha: 2.2, beta: 2.2, ..params });
        let r = b
            .run(&format!("lln_diag n={n}"), 1.0, || diag.forward(&q, &k, &v, &FULL))
            .clone();
        push(&mut records, "lln_diag", n, &r);

        // Backward rows (the native-training hot path): flash-style
        // recompute softmax backward — O(live pairs) like the forward,
        // capped with the other quadratic-cost baselines — and the
        // linear-class reverse-sweep backward (O(n·d²), every n).  The
        // forward statistics are saved once outside the timer, exactly
        // as a training step would hold them.
        let d_out = Mat::gaussian(n, d, 1.0, &mut rng);
        if n <= PR1_BASELINE_MAX_N {
            let (o, rm, rs) = crate::attention::grad::fused_softmax_attention_spec_fwd_train(
                &q, &k, &v, &FULL, params.tile,
            );
            let r = b
                .run(&format!("softmax_fused_bwd n={n}"), 1.0, || {
                    crate::attention::grad::fused_softmax_attention_spec_bwd(
                        &q, &k, &v, &FULL, &o, &rm, &rs, &d_out, params.tile,
                    )
                })
                .clone();
            push(&mut records, "softmax_fused_bwd", n, &r);

            // The same backward through the compute pool at the
            // session's resolved worker count.
            let r = b
                .run(&format!("softmax_fused_bwd_par n={n}"), 1.0, || {
                    crate::attention::grad::fused_softmax_attention_spec_bwd_par(
                        &q, &k, &v, &FULL, &o, &rm, &rs, &d_out, params.tile, params.threads,
                    )
                })
                .clone();
            push(&mut records, "softmax_fused_bwd_par", n, &r);

            // Multi-head flavor of the same backward: 4 heads, each a
            // fused recompute backward over its own d/4 column band —
            // the per-(seq, head) unit the native multi-head attention
            // op's backward executes.
            const HEADS: usize = 4;
            if d % HEADS == 0 {
                let dh = d / HEADS;
                let col_band = |m: &Mat, h: usize| {
                    let mut out = Mat::zeros(m.rows(), dh);
                    for i in 0..m.rows() {
                        out.row_mut(i).copy_from_slice(&m.row(i)[h * dh..(h + 1) * dh]);
                    }
                    out
                };
                let slices: Vec<_> = (0..HEADS)
                    .map(|h| {
                        let (qh, kh, vh, dh_out) =
                            (col_band(&q, h), col_band(&k, h), col_band(&v, h), col_band(&d_out, h));
                        let (oh, rmh, rsh) =
                            crate::attention::grad::fused_softmax_attention_spec_fwd_train(
                                &qh, &kh, &vh, &FULL, params.tile,
                            );
                        (qh, kh, vh, dh_out, oh, rmh, rsh)
                    })
                    .collect();
                let r = b
                    .run(&format!("softmax_fused_bwd_heads n={n} (x{HEADS} heads)"), 1.0, || {
                        let mut acc = 0.0f32;
                        for (qh, kh, vh, dh_out, oh, rmh, rsh) in &slices {
                            let (dqh, _, _) = crate::attention::grad::fused_softmax_attention_spec_bwd(
                                qh, kh, vh, &FULL, oh, rmh, rsh, dh_out, params.tile,
                            );
                            acc += dqh.data()[0];
                        }
                        acc
                    })
                    .clone();
                push(&mut records, "softmax_fused_bwd_heads", n, &r);
            }
        }
        {
            let pq = crate::attention::lln_features(&q, 2.2);
            let pk = crate::attention::lln_features(&k, 2.2);
            let lout = crate::attention::linear_attention_spec(
                &pq, &pk, &v, &FULL, params.chunk, params.threads,
            );
            let r = b
                .run(&format!("lln_bwd n={n}"), 1.0, || {
                    crate::attention::grad::linear_attention_spec_bwd(
                        &pq, &pk, &v, &FULL, &lout, &d_out,
                    )
                })
                .clone();
            push(&mut records, "lln_bwd", n, &r);

            let r = b
                .run(&format!("lln_bwd_par n={n}"), 1.0, || {
                    crate::attention::grad::linear_attention_spec_bwd_par(
                        &pq, &pk, &v, &FULL, &lout, &d_out, params.chunk, params.threads,
                    )
                })
                .clone();
            push(&mut records, "lln_bwd_par", n, &r);
        }
    }

    // Small-matmul threshold pin: a 48×48 output (2304 elements, under
    // PAR_MIN_ELEMS = 4096) must cost the same through par_matmul as
    // through plain matmul — the pair that keeps the fallback honest.
    {
        let sn = 48;
        let mut rng = crate::rng::Pcg64::seed(0x51AA11);
        let a = Mat::gaussian(sn, d, 1.0, &mut rng);
        let bm = Mat::gaussian(d, sn, 1.0, &mut rng);
        let r = b.run(&format!("matmul_small n={sn}"), 1.0, || a.matmul(&bm)).clone();
        push(&mut records, "matmul_small", sn, &r);
        let r = b
            .run(&format!("par_matmul_small n={sn}"), 1.0, || a.par_matmul(&bm, params.threads))
            .clone();
        push(&mut records, "par_matmul_small", sn, &r);
    }

    // End-to-end native train-step rows at 1/2/4 data-parallel shards
    // (fixed tiny shape, softmax attention): the dp2/dp4-vs-dp1 pairs
    // quote the gradient-sharding speedup the PR-9 compute pool buys.
    // Per-shard math is scheduling-independent, so every row optimizes
    // the same bitwise step.
    {
        use crate::training::native::{NativeShape, NativeStep, TrainStep};
        let shape = NativeShape {
            batch: 4,
            seqlen: 64,
            d_model: 32,
            heads: 2,
            layers: 2,
            ff: 64,
            vocab: 1024,
            seed: 0xD9,
        };
        let mut corpus = crate::data::Corpus::new(shape.vocab, 0xD9);
        let batch = corpus.mlm_batch(shape.batch, shape.seqlen, 0.15);
        for (name, dp) in
            [("train_step_dp1", 1usize), ("train_step_dp2", 2), ("train_step_dp4", 4)]
        {
            let mut step = NativeStep::new(crate::attention::Method::Softmax, shape)
                .expect("bench native step");
            step.set_data_parallel(dp);
            let r = b
                .run(&format!("{name} b={} n={}", shape.batch, shape.seqlen), 1.0, || {
                    step.step(1e-3, &batch).expect("bench train step").loss
                })
                .clone();
            push(&mut records, name, shape.seqlen, &r);
        }
    }

    // Decode-state footprint per storage precision: a real KvCache fed
    // the largest probed sequence, reporting its own state_bytes() —
    // the `kv_state_bytes_*` rows the docs/CONFIG.md scorecard quotes.
    let t = sizes.iter().copied().max().unwrap_or(0).min(PR1_BASELINE_MAX_N);
    let mut memory = Vec::new();
    if t > 0 {
        use crate::lowp::Precision;
        let mut rng = crate::rng::Pcg64::seed(0xB17E5);
        let kr = Mat::gaussian(t, d, 1.0, &mut rng);
        let vr = Mat::gaussian(t, d, 1.0, &mut rng);
        for (name, prec) in [
            ("kv_state_bytes_f32", Precision::F32),
            ("kv_state_bytes_bf16", Precision::Bf16),
            ("kv_state_bytes_f16", Precision::F16),
            ("kv_state_bytes_int8", Precision::Int8Kv),
        ] {
            let mut cache = crate::attention::KvCache::with_precision(d, d, prec);
            for i in 0..t {
                cache.push(kr.row(i), vr.row(i));
            }
            memory.push(MemoryRecord { name, tokens: t, bytes: cache.state_bytes() });
        }
    }

    KernelReport { d, threads, records, memory }
}

/// Minimal `--flag value` / `--flag=value` scan for the harness-less
/// bench targets (`cargo bench -- --json path`); ignores everything it
/// does not recognize (cargo itself passes `--bench`).
pub fn bench_arg(name: &str) -> Option<String> {
    let eq_prefix = format!("--{name}=");
    let bare = format!("--{name}");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if let Some(v) = a.strip_prefix(&eq_prefix) {
            return Some(v.to_string());
        }
        if a == bare {
            return args.next();
        }
    }
    None
}

/// [`bench_arg`] parsed as usize (None on absent or unparsable).
pub fn bench_arg_usize(name: &str) -> Option<usize> {
    bench_arg(name).and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bench { warmup_iters: 0, min_iters: 5, max_iters: 10, time_budget_secs: 0.2, results: vec![] };
        let r = b.run("noop", 1.0, || 42u64).clone();
        assert!(r.samples.len() >= 5);
        assert!(r.mean() >= 0.0);
        assert!(r.percentile(50.0) <= r.percentile(95.0) + 1e-12);
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let mut b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 3, time_budget_secs: 100.0, results: vec![] };
        let r = b.run("capped", 0.0, || ()).clone();
        assert!(r.samples.len() <= 3);
    }

    #[test]
    fn kernel_report_speedups_and_json_shape() {
        let rec = |name: &'static str, n: usize, mean_ns: f64| KernelRecord {
            name,
            n,
            mean_ns,
            p50_ns: mean_ns,
            iters: 3,
        };
        let report = KernelReport {
            d: 64,
            threads: 4,
            records: vec![
                rec("softmax_pipeline_pr1", 4096, 8000.0),
                rec("softmax_fused", 4096, 2000.0),
                rec("softmax_fused", 8192, 9000.0),
            ],
            memory: vec![MemoryRecord { name: "kv_state_bytes_f32", tokens: 512, bytes: 262144 }],
        };
        let sp = report.speedup("softmax_fused", "softmax_pipeline_pr1", 4096).unwrap();
        assert!((sp - 4.0).abs() < 1e-9);
        // No pr1 measurement at 8192 -> no derived pair there.
        assert!(report.speedup("softmax_fused", "softmax_pipeline_pr1", 8192).is_none());
        assert_eq!(report.speedups().len(), 1);
        let json = report.to_json();
        assert!(json.contains("\"bench\": \"kernels\""));
        assert!(json.contains("\"softmax_fused_vs_softmax_pipeline_pr1_n4096\": 4.00"));
        assert!(json.contains("\"name\": \"softmax_fused\", \"n\": 8192"));
        assert!(json.contains("\"name\": \"kv_state_bytes_f32\", \"tokens\": 512, \"bytes\": 262144"));
        assert!(crate::util::json::Json::parse(&json).is_ok(), "unparsable JSON:\n{json}");
        // Balanced braces/brackets as a cheap well-formedness check.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn run_kernel_bench_produces_records_at_small_n() {
        let mut b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, time_budget_secs: 0.01, results: vec![] };
        let report = run_kernel_bench(&mut b, &[64], 8, crate::attention::BackendParams::default());
        for name in [
            "softmax_pipeline_pr1",
            "softmax_pipeline_blocked",
            "softmax_fused",
            "softmax_fused_causal",
            "softmax_masked_dense_causal",
            "quadratic_fused",
            "lln_streamed",
            "lln_causal",
            "lln_decode_step",
            "softmax_decode_step",
            "lln_diag",
            "matmul_t_pr1",
            "matmul_t_blocked",
            "softmax_fused_bwd",
            "softmax_fused_bwd_par",
            "softmax_fused_bwd_heads",
            "lln_bwd",
            "lln_bwd_par",
            "matmul_t_spec",
            "matmul_t_gen",
            "softmax_decode_spec",
            "softmax_decode_gen",
            "lln_prefix_spec",
            "lln_prefix_gen",
        ] {
            assert!(report.mean_ns(name, 64).is_some(), "{name} missing");
        }
        // The decode-state footprint rows come from a real KvCache: at
        // t=64, d=8 the precisions land at exactly (d + dv) * t * width
        // (+ the int8 per-row tables).
        let mem = |name: &str| report.memory.iter().find(|m| m.name == name).unwrap().bytes;
        assert_eq!(mem("kv_state_bytes_f32"), 64 * 16 * 4);
        assert_eq!(mem("kv_state_bytes_bf16"), 64 * 16 * 2);
        assert_eq!(mem("kv_state_bytes_f16"), 64 * 16 * 2);
        assert_eq!(mem("kv_state_bytes_int8"), 64 * 16 + 2 * 64 * 8);
        assert!(2 * mem("kv_state_bytes_int8") <= mem("kv_state_bytes_f32"));
        assert!(report
            .speedup("softmax_fused", "softmax_pipeline_pr1", 64)
            .is_some());
        // The causal acceptance pair must be derivable from one run.
        assert!(report
            .speedup("softmax_fused_causal", "softmax_masked_dense_causal", 64)
            .is_some());
        // The amortized decode-vs-prefill pairs must be derivable too.
        assert!(report.speedup("softmax_decode_step", "softmax_fused_causal", 64).is_some());
        assert!(report.speedup("lln_decode_step", "lln_causal", 64).is_some());
        // And the new backward-vs-forward cost pairs.
        assert!(report.speedup("softmax_fused", "softmax_fused_bwd", 64).is_some());
        assert!(report.speedup("lln_streamed", "lln_bwd", 64).is_some());
        // Pooled-backward pairs ride the same run.
        assert!(report.speedup("softmax_fused_bwd_par", "softmax_fused_bwd", 64).is_some());
        assert!(report.speedup("lln_bwd_par", "lln_bwd", 64).is_some());
        // Multi-head backward rides the same n as the single-head row.
        assert!(report.speedup("softmax_fused_bwd_heads", "softmax_fused_bwd", 64).is_some());
        // Data-parallel train-step rows live at their own fixed n=64.
        assert!(report.speedup("train_step_dp2", "train_step_dp1", 64).is_some());
        assert!(report.speedup("train_step_dp4", "train_step_dp1", 64).is_some());
        // The small-matmul fallback pair lives at its own fixed n.
        assert!(report.mean_ns("matmul_small", 48).is_some());
        assert!(report.mean_ns("par_matmul_small", 48).is_some());
        assert!(report.speedup("par_matmul_small", "matmul_small", 48).is_some());
        // The monomorphized-vs-generic gate pairs.
        assert!(report.speedup("matmul_t_spec", "matmul_t_gen", 64).is_some());
        assert!(report.speedup("softmax_decode_spec", "softmax_decode_gen", 64).is_some());
        assert!(report.speedup("lln_prefix_spec", "lln_prefix_gen", 64).is_some());
    }

    #[test]
    fn baseline_gate_flags_only_regressed_spec_rows() {
        let rec = |name: &'static str, n: usize, mean_ns: f64| KernelRecord {
            name,
            n,
            mean_ns,
            p50_ns: mean_ns,
            iters: 3,
        };
        let report = KernelReport {
            d: 64,
            threads: 4,
            records: vec![
                rec("matmul_t_spec", 1024, 1300.0),     // +30%: over the gate
                rec("softmax_decode_spec", 1024, 1100.0), // +10%: within it
                rec("lln_prefix_gen", 1024, 9000.0),    // generic rows never gate
            ],
            memory: vec![],
        };
        let baseline = r#"{
          "results": [
            {"name": "matmul_t_spec", "n": 1024, "ns_per_op": 1000, "p50_ns": 1000, "iters": 3},
            {"name": "softmax_decode_spec", "n": 1024, "ns_per_op": 1000, "p50_ns": 1000, "iters": 3},
            {"name": "lln_prefix_spec", "n": 1024, "ns_per_op": 0, "p50_ns": 0, "iters": 0},
            {"name": "lln_prefix_gen", "n": 1024, "ns_per_op": 10, "p50_ns": 10, "iters": 3},
            {"name": "matmul_t_spec", "n": 4096, "ns_per_op": 1000, "p50_ns": 1000, "iters": 3}
          ]
        }"#;
        let regs = spec_regressions(&report, baseline, 0.25).unwrap();
        // Only the genuinely regressed spec row fails: the within-gate
        // row, the zero-ns bootstrap row, the generic row, and the
        // (name, n) point absent from the new report are all skipped.
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("matmul_t_spec n=1024"), "{}", regs[0]);
        // An empty or zero-only baseline (the committed bootstrap)
        // gates nothing; garbage input errors instead of passing.
        assert!(spec_regressions(&report, "{\"results\": []}", 0.25).unwrap().is_empty());
        assert!(spec_regressions(&report, "not json", 0.25).is_err());
        assert!(spec_regressions(&report, "{}", 0.25).is_err());
    }

    #[test]
    fn zero_budget_bench_still_yields_a_sample() {
        // Regression: a zero-iteration configuration used to produce an
        // empty sample set whose mean/percentile were NaN (and whose
        // report line could panic an interrupted `lln bench --json`).
        let mut b = Bench {
            warmup_iters: 0,
            min_iters: 0,
            max_iters: 0,
            time_budget_secs: 0.0,
            results: vec![],
        };
        let r = b.run("starved", 1.0, || 1u32).clone();
        assert!(!r.samples.is_empty(), "must take at least one sample");
        assert!(r.mean().is_finite() && r.percentile(50.0).is_finite());
        assert!(r.throughput().is_finite());
        // A genuinely empty result (interrupted run artifact) reports
        // zeros, never NaN or a panic.
        let empty = BenchResult { name: "empty".into(), samples: vec![], units_per_iter: 1.0 };
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.percentile(95.0), 0.0);
        assert_eq!(empty.std(), 0.0);
        assert_eq!(empty.throughput(), 0.0);
        let _ = empty.report_line();
    }

    #[test]
    fn sparse_report_json_skips_absent_pairs() {
        // Regression: a report whose baseline rows are capped (softmax
        // stops at n=4096) or missing (interrupted run) must emit
        // well-formed JSON with only the derivable pairs — `lln bench
        // --json` used to be crashable on absent pair lookups.
        let rec = |name: &'static str, n: usize, mean_ns: f64| KernelRecord {
            name,
            n,
            mean_ns,
            p50_ns: mean_ns,
            iters: 1,
        };
        // Only one method measured: no pair is derivable at all.
        let lonely = KernelReport {
            d: 64,
            threads: 2,
            records: vec![rec("lln_streamed", 8192, 5e5)],
            memory: vec![],
        };
        assert!(lonely.speedups().is_empty());
        assert!(lonely.speedup("softmax_fused", "softmax_pipeline_pr1", 8192).is_none());
        let json = lonely.to_json();
        assert!(crate::util::json::Json::parse(&json).is_ok(), "unparsable JSON:\n{json}");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        // Mixed: the fast row exists at 8192 where the capped baseline
        // does not — that pair is skipped, the 4096 pair survives.
        let mixed = KernelReport {
            d: 64,
            threads: 2,
            records: vec![
                rec("softmax_fused", 4096, 1e6),
                rec("softmax_fused", 8192, 4e6),
                rec("softmax_fused_bwd", 4096, 2.5e6),
            ],
            memory: vec![],
        };
        let pairs = mixed.speedups();
        assert_eq!(pairs.len(), 1);
        assert_eq!((pairs[0].0, pairs[0].1, pairs[0].2), ("softmax_fused", "softmax_fused_bwd", 4096));
        assert!(crate::util::json::Json::parse(&mixed.to_json()).is_ok());
    }
}
