//! Training metrics: JSONL log writer + in-memory history (fig. 8/9
//! curves are rendered from these records).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// One logged record (a superset of what each experiment uses).
#[derive(Clone, Debug)]
pub struct Record {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f64,
    pub alpha: Option<f32>,
    pub beta: Option<f32>,
    pub extra: Vec<(String, f64)>,
}

/// Append-only JSONL metrics log + in-memory history.
pub struct MetricsLog {
    path: Option<PathBuf>,
    pub history: Vec<Record>,
}

impl MetricsLog {
    /// In-memory only.
    pub fn ephemeral() -> Self {
        Self { path: None, history: Vec::new() }
    }

    /// Backed by a JSONL file (created/truncated).
    pub fn create(path: &Path) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        std::fs::write(path, b"").with_context(|| format!("creating {}", path.display()))?;
        Ok(Self { path: Some(path.to_path_buf()), history: Vec::new() })
    }

    pub fn log(&mut self, rec: Record) -> Result<()> {
        if let Some(path) = &self.path {
            let mut pairs = vec![
                ("step", Json::Num(rec.step as f64)),
                ("loss", Json::Num(rec.loss as f64)),
                ("grad_norm", Json::Num(rec.grad_norm as f64)),
                ("lr", Json::Num(rec.lr)),
            ];
            if let Some(a) = rec.alpha {
                pairs.push(("alpha", Json::Num(a as f64)));
            }
            if let Some(b) = rec.beta {
                pairs.push(("beta", Json::Num(b as f64)));
            }
            for (k, v) in &rec.extra {
                pairs.push((k.as_str(), Json::Num(*v)));
            }
            let line = obj(pairs).to_string_compact();
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(path)
                .with_context(|| format!("appending {}", path.display()))?;
            writeln!(f, "{line}")?;
        }
        self.history.push(rec);
        Ok(())
    }

    /// Smoothed loss curve (trailing window mean) for compact reports.
    pub fn smoothed_loss(&self, window: usize) -> Vec<(usize, f64)> {
        let w = window.max(1);
        self.history
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let lo = i.saturating_sub(w - 1);
                let slice = &self.history[lo..=i];
                let mean = slice.iter().map(|r| r.loss as f64).sum::<f64>() / slice.len() as f64;
                (r.step, mean)
            })
            .collect()
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.history.last().map(|r| r.loss)
    }

    pub fn max_grad_norm(&self) -> f64 {
        self.history.iter().map(|r| r.grad_norm as f64).fold(0.0, f64::max)
    }
}

/// Render an ASCII sparkline of a series (terminal loss curves).
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().cloned().fold(f64::MAX, f64::min);
    let hi = values.iter().cloned().fold(f64::MIN, f64::max);
    let span = (hi - lo).max(1e-12);
    let step = (values.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < values.len() && out.chars().count() < width {
        let v = values[i as usize];
        let idx = (((v - lo) / span) * 7.0).round() as usize;
        out.push(BARS[idx.min(7)]);
        i += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32) -> Record {
        Record { step, loss, grad_norm: 1.0, lr: 1e-3, alpha: None, beta: None, extra: vec![] }
    }

    #[test]
    fn jsonl_round_trip() {
        let tmp = std::env::temp_dir().join("lln_metrics_test.jsonl");
        let mut log = MetricsLog::create(&tmp).unwrap();
        log.log(Record { alpha: Some(2.1), ..rec(1, 5.0) }).unwrap();
        log.log(rec(2, 4.5)).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("step").unwrap().as_usize(), Some(1));
        assert!((v.get("alpha").unwrap().as_f64().unwrap() - 2.1).abs() < 1e-6);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn smoothing_window() {
        let mut log = MetricsLog::ephemeral();
        for (i, l) in [4.0f32, 2.0, 6.0].iter().enumerate() {
            log.log(rec(i, *l)).unwrap();
        }
        let s = log.smoothed_loss(2);
        assert!((s[0].1 - 4.0).abs() < 1e-9);
        assert!((s[1].1 - 3.0).abs() < 1e-9);
        assert!((s[2].1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn sparkline_is_width_bounded() {
        let vals: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let s = sparkline(&vals, 20);
        assert!(s.chars().count() <= 20);
        assert!(!s.is_empty());
    }
}
