//! GLUE-like synthetic classification tasks (Table 1 stand-ins).
//!
//! Four tasks mirror the *kinds* of reasoning in MNLI / QNLI / QQP /
//! SST-2, each parameterized so the class signal requires attention over
//! token sets (not just position-0 features), with controllable
//! long-range separation between evidence tokens.

use super::special;
use crate::rng::Pcg64;

/// One classification batch in the AOT train-step layout.
#[derive(Clone, Debug)]
pub struct ClsBatch {
    pub batch: usize,
    pub seqlen: usize,
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
}

/// The four Table-1 tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlueTask {
    /// MNLI-like: premise/hypothesis entailment (3 classes).
    Nli,
    /// QNLI-like: does the context contain the queried token? (2 classes)
    Qnli,
    /// QQP-like: are the two segments paraphrases? (2 classes)
    Qqp,
    /// SST-2-like: sentiment from class-conditional token frequencies.
    Sst2,
}

impl GlueTask {
    pub const ALL: [GlueTask; 4] = [GlueTask::Nli, GlueTask::Qnli, GlueTask::Qqp, GlueTask::Sst2];

    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::Nli => "MNLI-like",
            GlueTask::Qnli => "QNLI-like",
            GlueTask::Qqp => "QQP-like",
            GlueTask::Sst2 => "SST2-like",
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            GlueTask::Nli => 3,
            _ => 2,
        }
    }
}

/// Generator with a held-out eval stream (seeded independently).
pub struct GlueGen {
    pub task: GlueTask,
    pub vocab_size: usize,
    pub seqlen: usize,
    rng: Pcg64,
}

impl GlueGen {
    pub fn new(task: GlueTask, vocab_size: usize, seqlen: usize, seed: u64) -> Self {
        Self { task, vocab_size, seqlen, rng: Pcg64::new(seed, task as u64 + 1) }
    }

    fn content(&mut self) -> i32 {
        special::FIRST_CONTENT
            + self.rng.below((self.vocab_size as i32 - special::FIRST_CONTENT) as u64) as i32
    }

    /// Sample one (tokens, label) example.
    pub fn example(&mut self) -> (Vec<i32>, i32) {
        match self.task {
            GlueTask::Nli => self.nli(),
            GlueTask::Qnli => self.qnli(),
            GlueTask::Qqp => self.qqp(),
            GlueTask::Sst2 => self.sst2(),
        }
    }

    pub fn batch(&mut self, batch: usize) -> ClsBatch {
        let mut tokens = Vec::with_capacity(batch * self.seqlen);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (t, l) = self.example();
            debug_assert_eq!(t.len(), self.seqlen);
            tokens.extend_from_slice(&t);
            labels.push(l);
        }
        ClsBatch { batch, seqlen: self.seqlen, tokens, labels }
    }

    fn frame(&self, premise: &[i32], hypothesis: &[i32]) -> Vec<i32> {
        // [CLS] premise [SEP] hypothesis [SEP] padding...
        let mut out = Vec::with_capacity(self.seqlen);
        out.push(special::CLS);
        out.extend_from_slice(premise);
        out.push(special::SEP);
        out.extend_from_slice(hypothesis);
        out.push(special::SEP);
        while out.len() < self.seqlen {
            out.push(special::PAD);
        }
        out.truncate(self.seqlen);
        out
    }

    /// MNLI-like: entail = hypothesis is a subset of premise tokens;
    /// contradict = hypothesis contains the premise's "negation pair"
    /// tokens (id XOR 1); neutral = fresh random tokens.
    fn nli(&mut self) -> (Vec<i32>, i32) {
        let plen = (self.seqlen - 3) * 2 / 3;
        let hlen = (self.seqlen - 3) - plen;
        let premise: Vec<i32> = (0..plen).map(|_| self.content()).collect();
        let label = self.rng.below(3) as i32;
        let hypothesis: Vec<i32> = match label {
            0 => {
                // entailment: sample from premise tokens
                (0..hlen)
                    .map(|_| premise[self.rng.below(plen as u64) as usize])
                    .collect()
            }
            1 => {
                // contradiction: premise tokens flipped to their "antonym"
                (0..hlen)
                    .map(|_| {
                        let t = premise[self.rng.below(plen as u64) as usize];
                        (t ^ 1).max(special::FIRST_CONTENT)
                    })
                    .collect()
            }
            _ => (0..hlen).map(|_| self.content()).collect(),
        };
        (self.frame(&premise, &hypothesis), label)
    }

    /// QNLI-like: hypothesis is a single query token; label 1 iff it
    /// occurs somewhere in the (long) premise — pure long-range lookup.
    fn qnli(&mut self) -> (Vec<i32>, i32) {
        let plen = self.seqlen - 4;
        let premise: Vec<i32> = (0..plen).map(|_| self.content()).collect();
        let positive = self.rng.below(2) == 1;
        let query = if positive {
            premise[self.rng.below(plen as u64) as usize]
        } else {
            // A token guaranteed absent: resample until not in premise.
            loop {
                let t = self.content();
                if !premise.contains(&t) {
                    break t;
                }
            }
        };
        (self.frame(&premise, &[query]), positive as i32)
    }

    /// QQP-like: paraphrase = second segment is a shuffle of the first.
    fn qqp(&mut self) -> (Vec<i32>, i32) {
        let plen = (self.seqlen - 3) / 2;
        let hlen = (self.seqlen - 3) - plen;
        let a: Vec<i32> = (0..plen).map(|_| self.content()).collect();
        let positive = self.rng.below(2) == 1;
        let b: Vec<i32> = if positive {
            let mut b: Vec<i32> = (0..hlen).map(|i| a[i % plen]).collect();
            self.rng.shuffle(&mut b);
            b
        } else {
            (0..hlen).map(|_| self.content()).collect()
        };
        (self.frame(&a, &b), positive as i32)
    }

    /// SST-2-like: two disjoint "sentiment lexicons" (low vs high token
    /// ranges); the class-consistent lexicon dominates 65/35.
    fn sst2(&mut self) -> (Vec<i32>, i32) {
        let n = self.seqlen - 2;
        let label = self.rng.below(2) as i32;
        let half = (self.vocab_size as i32 - special::FIRST_CONTENT) / 2;
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..n {
            let from_class = self.rng.f64() < 0.65;
            let cls = if from_class { label } else { 1 - label };
            let base = special::FIRST_CONTENT + cls * half;
            tokens.push(base + self.rng.below(half as u64) as i32);
        }
        let mut out = vec![special::CLS];
        out.extend(tokens);
        out.push(special::SEP);
        while out.len() < self.seqlen {
            out.push(special::PAD);
        }
        (out, label)
    }

    /// Majority-class floor for this task (accuracy baseline).
    pub fn chance_accuracy(&self) -> f64 {
        1.0 / self.task.num_classes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_have_exact_length_and_valid_labels() {
        for task in GlueTask::ALL {
            let mut g = GlueGen::new(task, 4096, 128, 1);
            for _ in 0..20 {
                let (t, l) = g.example();
                assert_eq!(t.len(), 128, "{task:?}");
                assert!((l as usize) < task.num_classes(), "{task:?} label {l}");
                assert!(t.iter().all(|&x| x >= 0 && (x as usize) < 4096));
            }
        }
    }

    #[test]
    fn batches_are_shaped() {
        let mut g = GlueGen::new(GlueTask::Qqp, 4096, 128, 2);
        let b = g.batch(16);
        assert_eq!(b.tokens.len(), 16 * 128);
        assert_eq!(b.labels.len(), 16);
    }

    #[test]
    fn labels_are_balanced() {
        for task in GlueTask::ALL {
            let mut g = GlueGen::new(task, 4096, 128, 3);
            let mut counts = vec![0usize; task.num_classes()];
            for _ in 0..600 {
                let (_, l) = g.example();
                counts[l as usize] += 1;
            }
            for &c in &counts {
                let frac = c as f64 / 600.0;
                let expect = 1.0 / task.num_classes() as f64;
                assert!((frac - expect).abs() < 0.1, "{task:?} {counts:?}");
            }
        }
    }

    #[test]
    fn qnli_signal_is_learnable_by_lookup() {
        // A literal scan of the premise decides the label perfectly.
        let mut g = GlueGen::new(GlueTask::Qnli, 4096, 128, 4);
        for _ in 0..50 {
            let (t, l) = g.example();
            // frame: [CLS] premise(124) [SEP] query [SEP]
            let premise = &t[1..125];
            let query = t[126];
            let present = premise.contains(&query);
            assert_eq!(present as i32, l);
        }
    }

    #[test]
    fn sst2_lexicons_separate() {
        let mut g = GlueGen::new(GlueTask::Sst2, 4096, 128, 5);
        let half = (4096 - special::FIRST_CONTENT) / 2;
        for _ in 0..50 {
            let (t, l) = g.example();
            let content: Vec<i32> =
                t.iter().copied().filter(|&x| x >= special::FIRST_CONTENT).collect();
            let low = content.iter().filter(|&&x| x < special::FIRST_CONTENT + half).count();
            let frac_low = low as f64 / content.len() as f64;
            if l == 0 {
                assert!(frac_low > 0.5, "{frac_low}");
            } else {
                assert!(frac_low < 0.5, "{frac_low}");
            }
        }
    }
}
