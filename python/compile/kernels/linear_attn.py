"""Pallas kernel: chunked linearized attention (the paper's hot path).

Computes  out = Phi(Q) (Phi(K)^T V) / (Phi(Q) sum_j Phi(K)_j)  in two
grid phases, never materializing the N x N matrix:

  phase A (reduce over K/V chunks):  KV[d, d] += Phi(K_blk)^T V_blk
                                      z[1, d]  += sum_rows Phi(K_blk)
  phase B (map over Q chunks):       out_blk = Phi(Q_blk) KV / (Phi(Q_blk) z^T)

TPU mapping (DESIGN.md §Hardware-Adaptation): the KV accumulator and z
normalizer live in VMEM across sequential grid steps while K/V chunks
stream HBM->VMEM via BlockSpec; both contractions are (block, d) x (d, d)
MXU matmuls.  Block sizes are multiples of 128 where the sequence allows.

Feature maps:
  * "lln":  Phi_Q(q) = e^{alpha q},  Phi_K(k) = e^{beta k}   (paper eq. 8)
  * "elu":  Phi(x) = elu(x) + 1                              (baseline)

alpha/beta enter as (1, 1) f32 tensors so the AOT train step can derive
them from live batch statistics (moment matching) inside the same HLO.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; correctness is what we validate here, TPU perf is modeled
in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EXP_CLAMP

DEFAULT_BLOCK = 128


def _phi(x, scale, feature_map):
    if feature_map == "lln":
        return jnp.exp(jnp.clip(scale * x, -EXP_CLAMP, EXP_CLAMP))
    if feature_map == "elu":
        return jax.nn.elu(x) + 1.0
    raise ValueError(f"unknown feature map {feature_map!r}")


def _kv_kernel(k_ref, v_ref, beta_ref, kv_ref, z_ref, *, feature_map):
    """Phase A: accumulate Phi(K)^T V and the normalizer row-sum."""
    pk = _phi(k_ref[...], beta_ref[0, 0], feature_map)     # (bk, d)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        kv_ref[...] = jnp.zeros_like(kv_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    kv_ref[...] += pk.T @ v_ref[...]
    z_ref[...] += jnp.sum(pk, axis=0, keepdims=True)


def _out_kernel(q_ref, alpha_ref, kv_ref, z_ref, o_ref, *, feature_map, eps):
    """Phase B: contract Phi(Q) chunks against the accumulated state."""
    pq = _phi(q_ref[...], alpha_ref[0, 0], feature_map)    # (bq, d)
    num = pq @ kv_ref[...]                                  # (bq, d)
    den = pq @ z_ref[...].T                                 # (bq, 1)
    o_ref[...] = num / (den + eps)


def linear_attention_pallas(
    q,
    k,
    v,
    alpha,
    beta,
    *,
    feature_map="lln",
    block_q=DEFAULT_BLOCK,
    block_k=DEFAULT_BLOCK,
    eps=1e-6,
    interpret=True,
):
    """Chunked linear attention over one head: q, k, v are (N, d).

    alpha/beta: () or (1, 1) f32 scalars (ignored by the elu map).
    N must divide by the block sizes (model.py pads).
    """
    n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    if n % block_q or n % block_k:
        raise ValueError(f"N={n} must be divisible by block sizes ({block_q}, {block_k})")
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    beta = jnp.asarray(beta, jnp.float32).reshape(1, 1)

    kv, z = pl.pallas_call(
        functools.partial(_kv_kernel, feature_map=feature_map),
        grid=(n // block_k,),
        in_specs=[
            pl.BlockSpec((block_k, d), lambda i: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(k, v, beta)

    out = pl.pallas_call(
        functools.partial(_out_kernel, feature_map=feature_map, eps=eps),
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(q, alpha, kv, z)
    return out


def lln_attention_pallas(q, k, v, alpha, beta, **kw):
    """Paper eq. 8 as a Pallas kernel."""
    return linear_attention_pallas(q, k, v, alpha, beta, feature_map="lln", **kw)


def elu_attention_pallas(q, k, v, **kw):
    """ELU linear-attention baseline through the same kernel."""
    one = jnp.ones((), jnp.float32)
    return linear_attention_pallas(q, k, v, one, one, feature_map="elu", **kw)
