//! Attention-concentration sweeps (paper fig. 2): entropy and spectral
//! gap of each kernel's stochastic matrix as functions of the input
//! spread (equivalently the inverse temperature).

use crate::attention::{attention_matrix, MomentMatcher, Method};
use crate::linalg::spectral_gap;
use crate::rng::Pcg64;
use crate::stats::attention_entropy;
use crate::tensor::Mat;

/// One point on a fig. 2 curve.
#[derive(Clone, Copy, Debug)]
pub struct ConcentrationPoint {
    /// Input std of q and k for this probe.
    pub sigma: f64,
    /// Implicit SA temperature 1/sigma^2 at this probe (sigma_q = sigma_k).
    pub temperature: f64,
    pub entropy: f64,
    pub spectral_gap: f64,
}

/// Sweep entropy + spectral gap for one method across input spreads.
///
/// `matched`: apply moment matching when the method is LLN (fig. 2
/// contrasts matched vs. unmatched).
pub fn concentration_profile(
    method: Method,
    sigmas: &[f64],
    n: usize,
    d: usize,
    matched: Option<&MomentMatcher>,
    seed: u64,
) -> Vec<ConcentrationPoint> {
    let mut out = Vec::with_capacity(sigmas.len());
    for (i, &sigma) in sigmas.iter().enumerate() {
        let mut rng = Pcg64::new(seed, i as u64);
        let q = Mat::gaussian(n, d, sigma as f32, &mut rng);
        let k = Mat::gaussian(n, d, sigma as f32, &mut rng);
        let (alpha, beta) = match (method, matched) {
            (Method::Lln | Method::LlnDiag, Some(mm)) => mm.alpha_beta(sigma, sigma),
            _ => (1.0, 1.0),
        };
        let p = attention_matrix(method, &q, &k, alpha, beta);
        out.push(ConcentrationPoint {
            sigma,
            temperature: 1.0 / (sigma * sigma).max(1e-12),
            entropy: attention_entropy(&p),
            spectral_gap: spectral_gap(&p, 400, 1e-8).gap,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIGMAS: [f64; 4] = [0.4, 0.8, 1.2, 1.6];

    #[test]
    fn softmax_entropy_decreases_with_sigma() {
        // Thm 3.2: entropy increases with temperature; temperature falls
        // as input spread grows, so entropy must fall along this sweep.
        let pts = concentration_profile(Method::Softmax, &SIGMAS, 96, 64, None, 1);
        for w in pts.windows(2) {
            assert!(w[1].entropy < w[0].entropy, "{pts:?}");
        }
    }

    #[test]
    fn matched_lln_tracks_softmax_entropy() {
        let mm = MomentMatcher::from_artifacts(std::path::Path::new("artifacts"))
            .unwrap_or(MomentMatcher { a: 0.21, b: -1.08 });
        let sm = concentration_profile(Method::Softmax, &SIGMAS, 96, 64, None, 2);
        let lln = concentration_profile(Method::Lln, &SIGMAS, 96, 64, Some(&mm), 2);
        let un = concentration_profile(Method::Lln, &SIGMAS, 96, 64, None, 2);
        // Mean absolute entropy deviation: matched must beat unmatched.
        let dev = |a: &[ConcentrationPoint], b: &[ConcentrationPoint]| {
            a.iter().zip(b).map(|(x, y)| (x.entropy - y.entropy).abs()).sum::<f64>() / a.len() as f64
        };
        assert!(dev(&lln, &sm) < dev(&un, &sm), "matched {} unmatched {}", dev(&lln, &sm), dev(&un, &sm));
    }

    #[test]
    fn relu_kernel_insensitive_to_temperature() {
        // Fig 2's point: scale-invariant kernels barely react to sigma.
        let pts = concentration_profile(Method::Relu, &SIGMAS, 96, 64, None, 3);
        let spread = pts.iter().map(|p| p.entropy).fold(f64::MIN, f64::max)
            - pts.iter().map(|p| p.entropy).fold(f64::MAX, f64::min);
        let sm = concentration_profile(Method::Softmax, &SIGMAS, 96, 64, None, 3);
        let sm_spread = sm.iter().map(|p| p.entropy).fold(f64::MIN, f64::max)
            - sm.iter().map(|p| p.entropy).fold(f64::MAX, f64::min);
        assert!(spread < 0.4 * sm_spread, "relu {spread} vs sm {sm_spread}");
    }

    #[test]
    fn gap_and_entropy_move_together_for_softmax() {
        let pts = concentration_profile(Method::Softmax, &SIGMAS, 96, 64, None, 4);
        for w in pts.windows(2) {
            assert!(w[1].spectral_gap <= w[0].spectral_gap + 0.05, "{pts:?}");
        }
    }
}
