//! Backward kernels for the native training loop (ROADMAP: "fused
//! backward pass (recompute-based, flash-style)").
//!
//! Two kernel classes, mirroring the forward split:
//!
//! * **Fused softmax / quadratic backward** — FlashAttention-style
//!   recompute: the forward saves only the per-row online-softmax
//!   statistics (`row_max`, `row_sum`) and the output; the backward
//!   re-streams the K/V tiles at or below each query row (causal +
//!   `key_len` masks honored through [`AttnSpec::row_limit`], exactly
//!   like the fused forward) and rebuilds each probability tile from
//!   the saved statistics.  The n×n score matrix is never
//!   materialized: the working set is O(tile) per query row, so the
//!   O(n·tile) memory story of the forward survives training.
//!
//! * **Linear-class backward** — the reverse-sweep counterpart of
//!   [`linear_attention_causal`](super::linear_attention_causal)'s
//!   prefix-state recurrence: a forward sweep replays the
//!   `(Σ φ(k)vᵀ, Σ φ(k))` prefix state to produce `dφ(q)` rows and the
//!   per-row denominators, and a reverse sweep accumulates the
//!   *suffix* state `(Σ φ(q)·dnumᵀ, Σ dden·φ(q))` to produce `dφ(k)`
//!   and `dv` rows — O(m·dv) state, never an n×n buffer.  Feature-map
//!   chain rules ([`lln_feature_bwd`], [`elu_feature_bwd`],
//!   [`relu_feature_bwd`]) lift the φ-space gradients back to q/k —
//!   including `dα`/`dβ` for LLN's `exp(α·q)` / `exp(β·k)` maps, which
//!   is what lets the native trainer learn the paper's fig. 9
//!   alpha/beta trajectories.
//!
//! The dense references ([`softmax_attention_spec_bwd_dense`]) and the
//! finite-difference properties in `rust/tests/prop_kernels.rs` pin
//! every kernel here; [`super::backend`] exposes them through
//! `AttentionBackend::{forward_train, backward}`.

use super::kernels::{self, softmax_attention_matrix_spec};
use super::{AttnSpec, EXP_CLAMP};
use crate::tensor::Mat;

// ---------------------------------------------------------------------------
// Fused softmax: recompute forward + backward
// ---------------------------------------------------------------------------

/// Fused softmax forward that also returns the per-row online-softmax
/// statistics the recompute backward needs: `(out, row_max, row_sum)`.
/// Same masking, scale, and O(n·tile) streaming as
/// [`fused_softmax_attention_spec`](super::fused_softmax_attention_spec)
/// (values agree to streaming tolerance; this variant walks rows
/// serially so the statistics land in one pass).  Fully masked rows
/// (`row_limit == 0`) report `row_sum == 0` and a zero output row.
pub fn fused_softmax_attention_spec_fwd_train(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
) -> (Mat, Vec<f32>, Vec<f32>) {
    fused_softmax_attention_spec_fwd_train_par(q, k, v, spec, tile, 1)
}

/// One query row of the fused softmax training forward: the online
/// `(m, l, acc)` recurrence over the row's live K/V tiles; returns the
/// row's `(row_max, row_sum)`.  Shared by the serial and pooled entry
/// points, so per-row floating-point order — a function of the row's
/// own tiles alone — is identical however the rows are partitioned.
#[allow(clippy::too_many_arguments)]
fn softmax_fwd_train_row(
    qrow: &[f32],
    kd: &[f32],
    vd: &[f32],
    d: usize,
    dv: usize,
    lim: usize,
    scale: f32,
    tile: usize,
    orow: &mut [f32],
    scores: &mut [f32],
) -> (f32, f32) {
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut t0 = 0;
    while t0 < lim {
        let tn = tile.min(lim - t0);
        let ktile = &kd[t0 * d..(t0 + tn) * d];
        crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
        let mut tile_max = f32::NEG_INFINITY;
        for s in scores[..tn].iter_mut() {
            *s *= scale;
            tile_max = tile_max.max(*s);
        }
        let m_new = m.max(tile_max);
        let correction = (m - m_new).exp();
        if correction != 1.0 {
            l *= correction;
            for a in orow.iter_mut() {
                *a *= correction;
            }
        }
        let mut tile_sum = 0.0f32;
        for (j, &s) in scores[..tn].iter().enumerate() {
            let p = (s - m_new).exp();
            tile_sum += p;
            let vrow = &vd[(t0 + j) * dv..(t0 + j + 1) * dv];
            for (a, &vv) in orow.iter_mut().zip(vrow) {
                *a += p * vv;
            }
        }
        l += tile_sum;
        m = m_new;
        t0 += tn;
    }
    if l > 0.0 {
        let inv = 1.0 / l;
        for a in orow.iter_mut() {
            *a *= inv;
        }
    } else {
        orow.fill(0.0);
    }
    (m, l)
}

/// [`fused_softmax_attention_spec_fwd_train`] with the query rows
/// partitioned across `threads` compute-pool tasks (0 = auto; causal
/// specs cut spans on cumulative live pairs like the fused forward).
/// Every row's math touches only that row's accumulators, so the
/// result is bitwise identical to the serial walk at any thread count.
pub fn fused_softmax_attention_spec_fwd_train_par(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
    threads: usize,
) -> (Mat, Vec<f32>, Vec<f32>) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut out = Mat::zeros(nq, dv);
    let mut row_max = vec![f32::NEG_INFINITY; nq];
    let mut row_sum = vec![0.0f32; nq];
    if nq == 0 || nk == 0 || dv == 0 {
        return (out, row_max, row_sum);
    }
    let scale = spec.resolve_scale(d);
    let tile = kernels::resolve_tile(tile).min(nk);
    let (kd, vd) = (k.data(), v.data());
    let spans = query_spans(nq, nk, spec, threads);
    if spans.len() <= 1 {
        let mut scores = vec![0.0f32; tile];
        for i in 0..nq {
            let lim = spec.row_limit(i, nk);
            let (m, l) = softmax_fwd_train_row(
                q.row(i),
                kd,
                vd,
                d,
                dv,
                lim,
                scale,
                tile,
                out.row_mut(i),
                &mut scores,
            );
            row_max[i] = m;
            row_sum[i] = l;
        }
        return (out, row_max, row_sum);
    }
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(spans.len());
        let mut out_rest = out.data_mut();
        let mut m_rest = row_max.as_mut_slice();
        let mut l_rest = row_sum.as_mut_slice();
        for &(row0, len) in &spans {
            let (o_c, o_t) = std::mem::take(&mut out_rest).split_at_mut(len * dv);
            out_rest = o_t;
            let (m_c, m_t) = std::mem::take(&mut m_rest).split_at_mut(len);
            m_rest = m_t;
            let (l_c, l_t) = std::mem::take(&mut l_rest).split_at_mut(len);
            l_rest = l_t;
            tasks.push(Box::new(move || {
                let mut scores = vec![0.0f32; tile];
                for r in 0..len {
                    let i = row0 + r;
                    let lim = spec.row_limit(i, nk);
                    let (m, l) = softmax_fwd_train_row(
                        q.row(i),
                        kd,
                        vd,
                        d,
                        dv,
                        lim,
                        scale,
                        tile,
                        &mut o_c[r * dv..(r + 1) * dv],
                        &mut scores,
                    );
                    m_c[r] = m;
                    l_c[r] = l;
                }
            }));
        }
        crate::util::compute_pool::scope(tasks);
    }
    (out, row_max, row_sum)
}

/// Query-row spans for the backward kernels: causal specs balance on
/// cumulative live pairs ([`kernels::balanced_causal_spans`] — the
/// backward's per-row cost is triangular exactly like the forward's),
/// rectangular specs split evenly.  `threads` is resolved here
/// (0 = auto).
fn query_spans(nq: usize, nk: usize, spec: &AttnSpec, threads: usize) -> Vec<(usize, usize)> {
    let t = crate::tensor::resolve_threads(threads);
    if spec.causal {
        kernels::balanced_causal_spans(nq, nk, spec, t)
    } else {
        crate::tensor::partition_rows(nq, t)
    }
}

/// Flash-style recompute backward of the fused softmax forward.
///
/// Inputs are the forward operands plus what
/// [`fused_softmax_attention_spec_fwd_train`] saved (`out`, `row_max`,
/// `row_sum`) and the output cotangent `d_out`; returns `(dq, dk, dv)`.
/// Per query row the K/V tiles below its [`AttnSpec::row_limit`] are
/// re-streamed, each probability rebuilt as
/// `p_ij = exp(scale·q_i·k_j − m_i) / l_i`, and the standard softmax
/// VJP applied:
///
/// ```text
/// δ_i   = dO_i · O_i                        (row dot)
/// dS_ij = p_ij (dO_i · v_j − δ_i)
/// dq_i  = scale · Σ_j dS_ij k_j ;  dk_j += scale · dS_ij q_i
/// dv_j += p_ij dO_i
/// ```
///
/// Working set: one O(tile) score buffer — no n×n matrix at any
/// length.  Fully masked rows (`row_sum == 0`) contribute nothing.
#[allow(clippy::too_many_arguments)]
pub fn fused_softmax_attention_spec_bwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    row_max: &[f32],
    row_sum: &[f32],
    d_out: &Mat,
    tile: usize,
) -> (Mat, Mat, Mat) {
    fused_softmax_attention_spec_bwd_par(q, k, v, spec, out, row_max, row_sum, d_out, tile, 1)
}

/// One query row of the fused softmax backward: re-streams the row's
/// live K/V tiles, writes the row's `dq`, and accumulates its `dS`/`p`
/// contributions into the caller's `dk`/`dv` buffers (flat
/// `(nk, d)` / `(nk, dv)` — the full matrices on the serial path, a
/// span-private partial on the pooled path).
#[allow(clippy::too_many_arguments)]
fn softmax_bwd_row(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    out: &Mat,
    d_out: &Mat,
    i: usize,
    lim: usize,
    m: f32,
    inv_l: f32,
    scale: f32,
    tile: usize,
    scores: &mut [f32],
    dqrow: &mut [f32],
    dk: &mut [f32],
    dv_g: &mut [f32],
) {
    let d = q.cols();
    let dv = v.cols();
    let kd = k.data();
    let qrow = q.row(i);
    let dorow = d_out.row(i);
    // δ_i = dO_i · O_i = Σ_j p_ij (dO_i · v_j), accumulated in f64
    // so the subtraction below stays well-conditioned.
    let mut delta = 0.0f64;
    for (a, b) in dorow.iter().zip(out.row(i)) {
        delta += *a as f64 * *b as f64;
    }
    let delta = delta as f32;
    dqrow.fill(0.0);
    let mut t0 = 0;
    while t0 < lim {
        let tn = tile.min(lim - t0);
        let ktile = &kd[t0 * d..(t0 + tn) * d];
        crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
        for j in 0..tn {
            let kj = t0 + j;
            let p = (scores[j] * scale - m).exp() * inv_l;
            let vrow = v.row(kj);
            let mut dp = 0.0f32;
            for (a, b) in dorow.iter().zip(vrow) {
                dp += a * b;
            }
            let ds = p * (dp - delta) * scale;
            let krow = k.row(kj);
            for (o, &x) in dqrow.iter_mut().zip(krow) {
                *o += ds * x;
            }
            let dkrow = &mut dk[kj * d..(kj + 1) * d];
            for (o, &x) in dkrow.iter_mut().zip(qrow) {
                *o += ds * x;
            }
            let dvrow = &mut dv_g[kj * dv..(kj + 1) * dv];
            for (o, &x) in dvrow.iter_mut().zip(dorow) {
                *o += p * x;
            }
        }
        t0 += tn;
    }
}

/// [`fused_softmax_attention_spec_bwd`] with the query rows partitioned
/// across `threads` compute-pool tasks (0 = auto).  `dq` rows are
/// span-local and bitwise identical to the serial path at any thread
/// count; `dk`/`dv` accumulate across query rows, so each span fills a
/// private partial and the partials are reduced in fixed span order —
/// the summation *association* (never the per-term order) depends on
/// the span count, exactly like the forward's prefix-tile partials.
#[allow(clippy::too_many_arguments)]
pub fn fused_softmax_attention_spec_bwd_par(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    row_max: &[f32],
    row_sum: &[f32],
    d_out: &Mat,
    tile: usize,
    threads: usize,
) -> (Mat, Mat, Mat) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    assert_eq!(out.shape(), (q.rows(), v.cols()), "out shape mismatch");
    assert!(row_max.len() >= q.rows() && row_sum.len() >= q.rows(), "saved stats too short");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut dq = Mat::zeros(nq, d);
    let mut dk = Mat::zeros(nk, d);
    let mut dv_g = Mat::zeros(nk, dv);
    if nq == 0 || nk == 0 || dv == 0 {
        return (dq, dk, dv_g);
    }
    let scale = spec.resolve_scale(d);
    let tile = kernels::resolve_tile(tile).min(nk);
    let spans = query_spans(nq, nk, spec, threads);
    if spans.len() <= 1 {
        let mut scores = vec![0.0f32; tile];
        for i in 0..nq {
            let lim = spec.row_limit(i, nk);
            if lim == 0 || row_sum[i] <= 0.0 {
                continue;
            }
            let (dk_flat, dv_flat) = (dk.data_mut(), dv_g.data_mut());
            softmax_bwd_row(
                q,
                k,
                v,
                out,
                d_out,
                i,
                lim,
                row_max[i],
                1.0 / row_sum[i],
                scale,
                tile,
                &mut scores,
                dq.row_mut(i),
                dk_flat,
                dv_flat,
            );
        }
        return (dq, dk, dv_g);
    }
    let mut dk_parts: Vec<Vec<f32>> = spans.iter().map(|_| vec![0.0f32; nk * d]).collect();
    let mut dv_parts: Vec<Vec<f32>> = spans.iter().map(|_| vec![0.0f32; nk * dv]).collect();
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(spans.len());
        let mut dq_rest = dq.data_mut();
        for (&(row0, len), (dk_p, dv_p)) in
            spans.iter().zip(dk_parts.iter_mut().zip(dv_parts.iter_mut()))
        {
            let (dq_c, dq_t) = std::mem::take(&mut dq_rest).split_at_mut(len * d);
            dq_rest = dq_t;
            tasks.push(Box::new(move || {
                let mut scores = vec![0.0f32; tile];
                for r in 0..len {
                    let i = row0 + r;
                    let lim = spec.row_limit(i, nk);
                    if lim == 0 || row_sum[i] <= 0.0 {
                        continue;
                    }
                    softmax_bwd_row(
                        q,
                        k,
                        v,
                        out,
                        d_out,
                        i,
                        lim,
                        row_max[i],
                        1.0 / row_sum[i],
                        scale,
                        tile,
                        &mut scores,
                        &mut dq_c[r * d..(r + 1) * d],
                        dk_p,
                        dv_p,
                    );
                }
            }));
        }
        crate::util::compute_pool::scope(tasks);
    }
    // Fixed span-order reduction: span 0's contributions land first,
    // then span 1's, … — the association is a function of the span
    // list alone, never of pool scheduling.
    for dk_p in &dk_parts {
        for (a, b) in dk.data_mut().iter_mut().zip(dk_p) {
            *a += b;
        }
    }
    for dv_p in &dv_parts {
        for (a, b) in dv_g.data_mut().iter_mut().zip(dv_p) {
            *a += b;
        }
    }
    (dq, dk, dv_g)
}

/// Dense reference backward of masked softmax attention: materializes
/// the row-stochastic matrix from
/// [`softmax_attention_matrix_spec`](super::softmax_attention_matrix_spec)
/// and applies the softmax VJP with full matrices.  O(n²) memory — the
/// parity anchor the fused recompute backward is property-tested
/// against, never a training path.
pub fn softmax_attention_spec_bwd_dense(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    d_out: &Mat,
) -> (Mat, Mat, Mat) {
    let p = softmax_attention_matrix_spec(q, k, spec);
    let dv = p.transpose().matmul(d_out);
    // ds_ij = p_ij (dp_ij − δ_i),  dp = dO Vᵀ,  δ_i = Σ_j p_ij dp_ij.
    let mut ds = d_out.matmul_t(v);
    for i in 0..p.rows() {
        let prow = p.row(i);
        let dsrow = ds.row_mut(i);
        let mut delta = 0.0f64;
        for (a, b) in prow.iter().zip(dsrow.iter()) {
            delta += *a as f64 * *b as f64;
        }
        let delta = delta as f32;
        for (o, &pv) in dsrow.iter_mut().zip(prow) {
            *o = pv * (*o - delta);
        }
    }
    let scale = spec.resolve_scale(q.cols());
    let dq = ds.matmul(k).scale(scale);
    let dk = ds.transpose().matmul(q).scale(scale);
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Linear class: reverse-sweep prefix-state backward
// ---------------------------------------------------------------------------

/// One query row's φ(q) gradient plus its `(1/den, dden)` pair, given
/// the prefix state `(S, z)` visible to that row:
///
/// ```text
/// den   = φq·z + ε          dnum = dO / den
/// dden  = −(O · dO) / den   dφq[f] = S[f,:]·dnum + dden·z[f]
/// ```
#[allow(clippy::too_many_arguments)]
fn row_linear_bwd_q(
    qrow: &[f32],
    dorow: &[f32],
    orow: &[f32],
    s_state: &[f32],
    z_state: &[f32],
    dv: usize,
    dqrow: &mut [f32],
    inv_den_out: &mut f32,
    dden_out: &mut f32,
) {
    let mut den = 0.0f32;
    for (&qf, &zf) in qrow.iter().zip(z_state) {
        den += qf * zf;
    }
    let inv = 1.0 / (den + kernels::EPS);
    let mut od = 0.0f32;
    for (a, b) in orow.iter().zip(dorow) {
        od += a * b;
    }
    let dden = -od * inv;
    for (f, dqf) in dqrow.iter_mut().enumerate() {
        let srow = &s_state[f * dv..(f + 1) * dv];
        let mut acc = 0.0f32;
        for (s, &go) in srow.iter().zip(dorow) {
            acc += s * go;
        }
        *dqf = acc * inv + dden * z_state[f];
    }
    *inv_den_out = inv;
    *dden_out = dden;
}

/// Fold one query row's cotangent into the reverse-suffix state:
/// `G[f,:] += φq[f] · dnum`, `h[f] += dden · φq[f]` with
/// `dnum = dO / den`.
fn accumulate_reverse_state(
    g_state: &mut [f32],
    h_state: &mut [f32],
    qrow: &[f32],
    dorow: &[f32],
    inv_den: f32,
    dden: f32,
    dv: usize,
) {
    for (f, &qf) in qrow.iter().enumerate() {
        h_state[f] += dden * qf;
        if qf != 0.0 {
            let dst = &mut g_state[f * dv..(f + 1) * dv];
            for (o, &go) in dst.iter_mut().zip(dorow) {
                *o += qf * go * inv_den;
            }
        }
    }
}

/// One live key row's `(dφk, dv)` from the suffix state `(G, h)` of
/// the queries that can see it: `dφk[f] = G[f,:]·v + h[f]`,
/// `dv += Σ_f φk[f]·G[f,:]`.
fn row_linear_bwd_k(
    krow: &[f32],
    vrow: &[f32],
    g_state: &[f32],
    h_state: &[f32],
    dv: usize,
    dkrow: &mut [f32],
    dvrow: &mut [f32],
) {
    for (f, dkf) in dkrow.iter_mut().enumerate() {
        let grow = &g_state[f * dv..(f + 1) * dv];
        let mut acc = 0.0f32;
        for (g, b) in grow.iter().zip(vrow) {
            acc += g * b;
        }
        *dkf = acc + h_state[f];
        let kf = krow[f];
        if kf != 0.0 {
            for (o, &g) in dvrow.iter_mut().zip(grow) {
                *o += kf * g;
            }
        }
    }
}

/// Backward of [`linear_attention_spec`](super::linear_attention_spec)
/// in feature space: given the lifted maps `φ(q)`, `φ(k)`, the values,
/// the saved forward output, and the cotangent `d_out`, returns
/// `(dφ(q), dφ(k), dv)`.
///
/// Causal specs run the reverse-sweep prefix-state recurrence (the
/// mirror of `linear_attention_causal`): a forward pass replays the
/// `(Σ φ(k)vᵀ, Σ φ(k))` prefix to emit each `dφ(q)` row and the
/// per-row denominators, then a reverse pass accumulates the suffix
/// state `(Σ φ(q)·dnumᵀ, Σ dden·φ(q))` — the state key row `j` needs
/// is exactly the queries `i ≥ j` — to emit `dφ(k)` / `dv` rows.
/// O(m·dv) state either way; no n×n buffer.  `key_len`-dead key rows
/// receive exact-zero gradients (they never entered the forward
/// state), and `spec.scale` is ignored exactly as the forward ignores
/// it.
pub fn linear_attention_spec_bwd(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    d_out: &Mat,
) -> (Mat, Mat, Mat) {
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), (phi_q.rows(), v.cols()), "out shape mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    let (nq, m) = phi_q.shape();
    let nk = phi_k.rows();
    let dv = v.cols();
    let mut d_phi_q = Mat::zeros(nq, m);
    let mut d_phi_k = Mat::zeros(nk, m);
    let mut d_v = Mat::zeros(nk, dv);
    if nq == 0 || dv == 0 || m == 0 {
        return (d_phi_q, d_phi_k, d_v);
    }
    let kl = spec.key_limit(nk);
    let mut inv_den = vec![0.0f32; nq];
    let mut dden = vec![0.0f32; nq];

    if spec.causal {
        assert_eq!(nq, nk, "causal linear backward requires aligned q/k row counts");
        // Forward prefix sweep: dφq rows + per-row denominators.
        let mut s_state = vec![0.0f32; m * dv];
        let mut z_state = vec![0.0f32; m];
        for i in 0..nq {
            if i < kl {
                kernels::accumulate_state(&mut s_state, &mut z_state, phi_k.row(i), v.row(i), dv);
            }
            let (iv, dd) = (&mut inv_den[i], &mut dden[i]);
            row_linear_bwd_q(
                phi_q.row(i),
                d_out.row(i),
                out.row(i),
                &s_state,
                &z_state,
                dv,
                d_phi_q.row_mut(i),
                iv,
                dd,
            );
        }
        // Reverse suffix sweep: key row j reads the queries i >= j.
        let mut g_state = vec![0.0f32; m * dv];
        let mut h_state = vec![0.0f32; m];
        for i in (0..nq).rev() {
            accumulate_reverse_state(
                &mut g_state,
                &mut h_state,
                phi_q.row(i),
                d_out.row(i),
                inv_den[i],
                dden[i],
                dv,
            );
            if i < kl {
                row_linear_bwd_k(
                    phi_k.row(i),
                    v.row(i),
                    &g_state,
                    &h_state,
                    dv,
                    d_phi_k.row_mut(i),
                    d_v.row_mut(i),
                );
            }
        }
    } else {
        // Bidirectional: every query reads the same state over the
        // live key prefix, and every live key reads every query.
        let mut s_state = vec![0.0f32; m * dv];
        let mut z_state = vec![0.0f32; m];
        for j in 0..kl {
            kernels::accumulate_state(&mut s_state, &mut z_state, phi_k.row(j), v.row(j), dv);
        }
        let mut g_state = vec![0.0f32; m * dv];
        let mut h_state = vec![0.0f32; m];
        for i in 0..nq {
            let (iv, dd) = (&mut inv_den[i], &mut dden[i]);
            row_linear_bwd_q(
                phi_q.row(i),
                d_out.row(i),
                out.row(i),
                &s_state,
                &z_state,
                dv,
                d_phi_q.row_mut(i),
                iv,
                dd,
            );
            accumulate_reverse_state(
                &mut g_state,
                &mut h_state,
                phi_q.row(i),
                d_out.row(i),
                inv_den[i],
                dden[i],
                dv,
            );
        }
        for j in 0..kl {
            row_linear_bwd_k(
                phi_k.row(j),
                v.row(j),
                &g_state,
                &h_state,
                dv,
                d_phi_k.row_mut(j),
                d_v.row_mut(j),
            );
        }
    }
    (d_phi_q, d_phi_k, d_v)
}

/// [`linear_attention_spec_bwd`] on the compute pool: the reverse-sweep
/// backward with both sweeps chunked exactly like
/// [`linear_attention_causal`](super::linear_attention_causal)'s
/// forward recurrence.  `chunk` is the state-carry granularity
/// (0 = 128 rows), `threads` the task count (0 = auto).
///
/// Causal specs run six phases — per-chunk prefix partials, serial
/// exclusive prefix carries, parallel per-chunk `dφq` replay, then the
/// mirror for the suffix: per-chunk reverse partials, serial exclusive
/// *suffix* carries, parallel per-chunk `dφk`/`dv` replay.  Summation
/// order per chunk is fixed, so results depend on `chunk` but never on
/// the worker count — the same determinism contract as the forward.
/// Non-causal specs use per-task state partials merged in fixed range
/// order plus row-local `dφq`/`dφk`/`dv` spans.  `threads <= 1` takes
/// the serial path byte-for-byte.
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_spec_bwd_par(
    phi_q: &Mat,
    phi_k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    d_out: &Mat,
    chunk: usize,
    threads: usize,
) -> (Mat, Mat, Mat) {
    let t = crate::tensor::resolve_threads(threads);
    let nq = phi_q.rows();
    if t <= 1 || nq <= 1 {
        return linear_attention_spec_bwd(phi_q, phi_k, v, spec, out, d_out);
    }
    assert_eq!(phi_q.cols(), phi_k.cols(), "feature dims differ");
    assert_eq!(phi_k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), (phi_q.rows(), v.cols()), "out shape mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    let m = phi_q.cols();
    let nk = phi_k.rows();
    let dv = v.cols();
    let mut d_phi_q = Mat::zeros(nq, m);
    let mut d_phi_k = Mat::zeros(nk, m);
    let mut d_v = Mat::zeros(nk, dv);
    if dv == 0 || m == 0 {
        return (d_phi_q, d_phi_k, d_v);
    }
    let kl = spec.key_limit(nk);
    let mut inv_den = vec![0.0f32; nq];
    let mut dden = vec![0.0f32; nq];
    let chunk = if chunk == 0 { 128 } else { chunk };

    if spec.causal {
        assert_eq!(nq, nk, "causal linear backward requires aligned q/k row counts");
        let n_chunks = nq.div_ceil(chunk);
        let groups = t.min(n_chunks);
        let chunks_per = n_chunks.div_ceil(groups);

        // F1: per-chunk (Σ φ(k)vᵀ, Σ φ(k)) prefix partials over live
        // key rows — identical to the forward recurrence's phase 1.
        let mut kv_part = vec![0.0f32; n_chunks * m * dv];
        let mut z_part = vec![0.0f32; n_chunks * m];
        {
            let kv_groups = kv_part.chunks_mut(chunks_per * m * dv);
            let z_groups = z_part.chunks_mut(chunks_per * m);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = kv_groups
                .zip(z_groups)
                .enumerate()
                .map(|(gi, (kv_g, z_g))| {
                    Box::new(move || {
                        let per_chunk = kv_g.chunks_mut(m * dv).zip(z_g.chunks_mut(m));
                        for (ci, (kv_c, z_c)) in per_chunk.enumerate() {
                            let c = gi * chunks_per + ci;
                            let lo = c * chunk;
                            let hi = ((c + 1) * chunk).min(nq).min(kl);
                            for i in lo..hi.max(lo) {
                                kernels::accumulate_state(kv_c, z_c, phi_k.row(i), v.row(i), dv);
                            }
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            crate::util::compute_pool::scope(tasks);
        }

        // F2 (serial): exclusive prefix carries.
        let mut carry_kv = vec![0.0f32; n_chunks * m * dv];
        let mut carry_z = vec![0.0f32; n_chunks * m];
        for c in 1..n_chunks {
            let (prev_kv, cur_kv) = carry_kv.split_at_mut(c * m * dv);
            let prev_kv = &prev_kv[(c - 1) * m * dv..];
            let part_kv = &kv_part[(c - 1) * m * dv..c * m * dv];
            for ((o, &a), &b) in cur_kv[..m * dv].iter_mut().zip(prev_kv).zip(part_kv) {
                *o = a + b;
            }
            let (prev_z, cur_z) = carry_z.split_at_mut(c * m);
            let prev_z = &prev_z[(c - 1) * m..];
            let part_z = &z_part[(c - 1) * m..c * m];
            for ((o, &a), &b) in cur_z[..m].iter_mut().zip(prev_z).zip(part_z) {
                *o = a + b;
            }
        }

        // F3: each chunk group replays its rows on its prefix carry —
        // dφq rows plus per-row (1/den, dden), all span-local writes.
        {
            let carry_kv = carry_kv.as_slice();
            let carry_z = carry_z.as_slice();
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(groups);
            let mut dq_rest = d_phi_q.data_mut();
            let mut iv_rest = inv_den.as_mut_slice();
            let mut dd_rest = dden.as_mut_slice();
            for gi in 0..groups {
                let lo_c = gi * chunks_per;
                let hi_c = ((gi + 1) * chunks_per).min(n_chunks);
                if lo_c >= hi_c {
                    continue;
                }
                let lo = lo_c * chunk;
                let rows = (hi_c * chunk).min(nq) - lo;
                let (dq_g, dq_t) = std::mem::take(&mut dq_rest).split_at_mut(rows * m);
                dq_rest = dq_t;
                let (iv_g, iv_t) = std::mem::take(&mut iv_rest).split_at_mut(rows);
                iv_rest = iv_t;
                let (dd_g, dd_t) = std::mem::take(&mut dd_rest).split_at_mut(rows);
                dd_rest = dd_t;
                tasks.push(Box::new(move || {
                    let mut state_kv = vec![0.0f32; m * dv];
                    let mut state_z = vec![0.0f32; m];
                    for c in lo_c..hi_c {
                        state_kv.copy_from_slice(&carry_kv[c * m * dv..(c + 1) * m * dv]);
                        state_z.copy_from_slice(&carry_z[c * m..(c + 1) * m]);
                        for i in c * chunk..((c + 1) * chunk).min(nq) {
                            if i < kl {
                                kernels::accumulate_state(
                                    &mut state_kv,
                                    &mut state_z,
                                    phi_k.row(i),
                                    v.row(i),
                                    dv,
                                );
                            }
                            let r = i - lo;
                            row_linear_bwd_q(
                                phi_q.row(i),
                                d_out.row(i),
                                out.row(i),
                                &state_kv,
                                &state_z,
                                dv,
                                &mut dq_g[r * m..(r + 1) * m],
                                &mut iv_g[r],
                                &mut dd_g[r],
                            );
                        }
                    }
                }));
            }
            crate::util::compute_pool::scope(tasks);
        }

        // B1: per-chunk reverse-suffix partials (Σ φ(q)dnumᵀ, Σ dden φ(q)),
        // each chunk's rows folded in reverse order like the serial sweep.
        let inv_den_ref = inv_den.as_slice();
        let dden_ref = dden.as_slice();
        let mut g_part = vec![0.0f32; n_chunks * m * dv];
        let mut h_part = vec![0.0f32; n_chunks * m];
        {
            let g_groups = g_part.chunks_mut(chunks_per * m * dv);
            let h_groups = h_part.chunks_mut(chunks_per * m);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = g_groups
                .zip(h_groups)
                .enumerate()
                .map(|(gi, (g_g, h_g))| {
                    Box::new(move || {
                        let per_chunk = g_g.chunks_mut(m * dv).zip(h_g.chunks_mut(m));
                        for (ci, (g_c, h_c)) in per_chunk.enumerate() {
                            let c = gi * chunks_per + ci;
                            let lo = c * chunk;
                            let hi = ((c + 1) * chunk).min(nq);
                            for i in (lo..hi).rev() {
                                accumulate_reverse_state(
                                    g_c,
                                    h_c,
                                    phi_q.row(i),
                                    d_out.row(i),
                                    inv_den_ref[i],
                                    dden_ref[i],
                                    dv,
                                );
                            }
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            crate::util::compute_pool::scope(tasks);
        }

        // B2 (serial): exclusive *suffix* carries — chunk c starts from
        // the reverse state of every chunk above it.
        let mut carry_g = vec![0.0f32; n_chunks * m * dv];
        let mut carry_h = vec![0.0f32; n_chunks * m];
        for c in (0..n_chunks.saturating_sub(1)).rev() {
            let (cur_g, next_g) = carry_g.split_at_mut((c + 1) * m * dv);
            let cur_g = &mut cur_g[c * m * dv..];
            let next_g = &next_g[..m * dv];
            let part_g = &g_part[(c + 1) * m * dv..(c + 2) * m * dv];
            for ((o, &a), &b) in cur_g.iter_mut().zip(next_g).zip(part_g) {
                *o = a + b;
            }
            let (cur_h, next_h) = carry_h.split_at_mut((c + 1) * m);
            let cur_h = &mut cur_h[c * m..];
            let next_h = &next_h[..m];
            let part_h = &h_part[(c + 1) * m..(c + 2) * m];
            for ((o, &a), &b) in cur_h.iter_mut().zip(next_h).zip(part_h) {
                *o = a + b;
            }
        }

        // B3: each chunk group replays its rows (in reverse) on its
        // suffix carry — dφk / dv rows for the live indices.
        {
            let carry_g = carry_g.as_slice();
            let carry_h = carry_h.as_slice();
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(groups);
            let mut dk_rest = d_phi_k.data_mut();
            let mut dvm_rest = d_v.data_mut();
            for gi in 0..groups {
                let lo_c = gi * chunks_per;
                let hi_c = ((gi + 1) * chunks_per).min(n_chunks);
                if lo_c >= hi_c {
                    continue;
                }
                let lo = lo_c * chunk;
                let rows = (hi_c * chunk).min(nq) - lo;
                let (dk_g, dk_t) = std::mem::take(&mut dk_rest).split_at_mut(rows * m);
                dk_rest = dk_t;
                let (dvm_g, dvm_t) = std::mem::take(&mut dvm_rest).split_at_mut(rows * dv);
                dvm_rest = dvm_t;
                tasks.push(Box::new(move || {
                    let mut state_g = vec![0.0f32; m * dv];
                    let mut state_h = vec![0.0f32; m];
                    for c in (lo_c..hi_c).rev() {
                        state_g.copy_from_slice(&carry_g[c * m * dv..(c + 1) * m * dv]);
                        state_h.copy_from_slice(&carry_h[c * m..(c + 1) * m]);
                        for i in (c * chunk..((c + 1) * chunk).min(nq)).rev() {
                            accumulate_reverse_state(
                                &mut state_g,
                                &mut state_h,
                                phi_q.row(i),
                                d_out.row(i),
                                inv_den_ref[i],
                                dden_ref[i],
                                dv,
                            );
                            if i < kl {
                                let r = i - lo;
                                row_linear_bwd_k(
                                    phi_k.row(i),
                                    v.row(i),
                                    &state_g,
                                    &state_h,
                                    dv,
                                    &mut dk_g[r * m..(r + 1) * m],
                                    &mut dvm_g[r * dv..(r + 1) * dv],
                                );
                            }
                        }
                    }
                }));
            }
            crate::util::compute_pool::scope(tasks);
        }
    } else {
        // Phase A: shared prefix state over the live keys from
        // per-*chunk* partials merged serially in chunk order — the
        // summation order is a function of (kl, chunk) alone, never of
        // the worker count, mirroring the causal path's contract.
        let mut s_state = vec![0.0f32; m * dv];
        let mut z_state = vec![0.0f32; m];
        if kl > 0 {
            let n_chunks = kl.div_ceil(chunk);
            let groups = t.min(n_chunks);
            let chunks_per = n_chunks.div_ceil(groups);
            let mut kv_part = vec![0.0f32; n_chunks * m * dv];
            let mut z_part = vec![0.0f32; n_chunks * m];
            {
                let kv_groups = kv_part.chunks_mut(chunks_per * m * dv);
                let z_groups = z_part.chunks_mut(chunks_per * m);
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = kv_groups
                    .zip(z_groups)
                    .enumerate()
                    .map(|(gi, (kv_g, z_g))| {
                        Box::new(move || {
                            let per_chunk = kv_g.chunks_mut(m * dv).zip(z_g.chunks_mut(m));
                            for (ci, (kv_c, z_c)) in per_chunk.enumerate() {
                                let c = gi * chunks_per + ci;
                                let lo = c * chunk;
                                let hi = ((c + 1) * chunk).min(kl);
                                for j in lo..hi {
                                    kernels::accumulate_state(
                                        kv_c,
                                        z_c,
                                        phi_k.row(j),
                                        v.row(j),
                                        dv,
                                    );
                                }
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                crate::util::compute_pool::scope(tasks);
            }
            for c in 0..n_chunks {
                for (a, b) in s_state.iter_mut().zip(&kv_part[c * m * dv..(c + 1) * m * dv]) {
                    *a += b;
                }
                for (a, b) in z_state.iter_mut().zip(&z_part[c * m..(c + 1) * m]) {
                    *a += b;
                }
            }
        }

        // Phase B: query chunks — row-local dφq plus per-chunk reverse
        // (G, h) partials, merged serially in chunk order (same
        // worker-count independence as phase A).
        let mut g_state = vec![0.0f32; m * dv];
        let mut h_state = vec![0.0f32; m];
        {
            let s_ref = s_state.as_slice();
            let z_ref = z_state.as_slice();
            let n_chunks = nq.div_ceil(chunk);
            let groups = t.min(n_chunks);
            let chunks_per = n_chunks.div_ceil(groups);
            let mut g_part = vec![0.0f32; n_chunks * m * dv];
            let mut h_part = vec![0.0f32; n_chunks * m];
            {
                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(groups);
                let mut dq_rest = d_phi_q.data_mut();
                let mut iv_rest = inv_den.as_mut_slice();
                let mut dd_rest = dden.as_mut_slice();
                let g_groups = g_part.chunks_mut(chunks_per * m * dv);
                let h_groups = h_part.chunks_mut(chunks_per * m);
                for (gi, (g_g, h_g)) in g_groups.zip(h_groups).enumerate() {
                    let lo = gi * chunks_per * chunk;
                    let rows = ((gi + 1) * chunks_per * chunk).min(nq) - lo;
                    let (dq_g, dq_t) = std::mem::take(&mut dq_rest).split_at_mut(rows * m);
                    dq_rest = dq_t;
                    let (iv_g, iv_t) = std::mem::take(&mut iv_rest).split_at_mut(rows);
                    iv_rest = iv_t;
                    let (dd_g, dd_t) = std::mem::take(&mut dd_rest).split_at_mut(rows);
                    dd_rest = dd_t;
                    tasks.push(Box::new(move || {
                        let per_chunk = g_g.chunks_mut(m * dv).zip(h_g.chunks_mut(m));
                        for (ci, (g_c, h_c)) in per_chunk.enumerate() {
                            let c = gi * chunks_per + ci;
                            for i in c * chunk..((c + 1) * chunk).min(nq) {
                                let r = i - lo;
                                row_linear_bwd_q(
                                    phi_q.row(i),
                                    d_out.row(i),
                                    out.row(i),
                                    s_ref,
                                    z_ref,
                                    dv,
                                    &mut dq_g[r * m..(r + 1) * m],
                                    &mut iv_g[r],
                                    &mut dd_g[r],
                                );
                                accumulate_reverse_state(
                                    g_c,
                                    h_c,
                                    phi_q.row(i),
                                    d_out.row(i),
                                    iv_g[r],
                                    dd_g[r],
                                    dv,
                                );
                            }
                        }
                    }));
                }
                crate::util::compute_pool::scope(tasks);
            }
            for c in 0..n_chunks {
                for (a, b) in g_state.iter_mut().zip(&g_part[c * m * dv..(c + 1) * m * dv]) {
                    *a += b;
                }
                for (a, b) in h_state.iter_mut().zip(&h_part[c * m..(c + 1) * m]) {
                    *a += b;
                }
            }
        }

        // Phase C: live key spans — row-local dφk / dv from the shared
        // reduced (G, h).
        if kl > 0 {
            let g_ref = g_state.as_slice();
            let h_ref = h_state.as_slice();
            let kspans = crate::tensor::partition_rows(kl, t);
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(kspans.len());
            let mut dk_rest = &mut d_phi_k.data_mut()[..kl * m];
            let mut dvm_rest = &mut d_v.data_mut()[..kl * dv];
            for &(row0, len) in &kspans {
                let (dk_g, dk_t) = std::mem::take(&mut dk_rest).split_at_mut(len * m);
                dk_rest = dk_t;
                let (dvm_g, dvm_t) = std::mem::take(&mut dvm_rest).split_at_mut(len * dv);
                dvm_rest = dvm_t;
                tasks.push(Box::new(move || {
                    for r in 0..len {
                        let j = row0 + r;
                        row_linear_bwd_k(
                            phi_k.row(j),
                            v.row(j),
                            g_ref,
                            h_ref,
                            dv,
                            &mut dk_g[r * m..(r + 1) * m],
                            &mut dvm_g[r * dv..(r + 1) * dv],
                        );
                    }
                }));
            }
            crate::util::compute_pool::scope(tasks);
        }
    }
    (d_phi_q, d_phi_k, d_v)
}

// ---------------------------------------------------------------------------
// Feature-map chain rules (φ-space gradients -> q/k space)
// ---------------------------------------------------------------------------

/// Chain rule through LLN's clamped-exp feature map
/// `φ(x) = exp(clamp(s·x))`: returns `(dx, ds)` given the input `x`,
/// the forward features `φ`, their cotangent `dφ`, and the exponent
/// `s` (alpha for queries, beta for keys).  Inside the clamp,
/// `dφ/dx = s·φ` and `dφ/ds = x·φ`; at saturation the derivative is
/// exactly zero (the clamp is flat there), which also keeps the
/// trained exponents from being pushed by saturated features.
pub fn lln_feature_bwd(x: &Mat, phi: &Mat, d_phi: &Mat, s: f32) -> (Mat, f32) {
    assert_eq!(x.shape(), phi.shape(), "x/phi shape mismatch");
    assert_eq!(x.shape(), d_phi.shape(), "x/d_phi shape mismatch");
    let mut dx = Mat::zeros(x.rows(), x.cols());
    let mut dscale = 0.0f64;
    for ((o, &xv), (&pv, &dp)) in dx
        .data_mut()
        .iter_mut()
        .zip(x.data())
        .zip(phi.data().iter().zip(d_phi.data()))
    {
        if (s * xv).abs() < EXP_CLAMP {
            *o = s * pv * dp;
            dscale += (xv * pv * dp) as f64;
        }
    }
    (dx, dscale as f32)
}

/// Chain rule through the ELU feature map
/// `φ(x) = x + 1 (x > 0) | exp(x) (x ≤ 0)`:
/// `dφ/dx = 1 (x > 0) | exp(x) (x ≤ 0)` — continuous at 0.
pub fn elu_feature_bwd(x: &Mat, d_phi: &Mat) -> Mat {
    assert_eq!(x.shape(), d_phi.shape(), "x/d_phi shape mismatch");
    let mut dx = d_phi.clone();
    for (o, &xv) in dx.data_mut().iter_mut().zip(x.data()) {
        if xv <= 0.0 {
            *o *= xv.exp();
        }
    }
    dx
}

/// Chain rule through the ReLU feature map: pass where `x > 0`.
pub fn relu_feature_bwd(x: &Mat, d_phi: &Mat) -> Mat {
    assert_eq!(x.shape(), d_phi.shape(), "x/d_phi shape mismatch");
    let mut dx = d_phi.clone();
    for (o, &xv) in dx.data_mut().iter_mut().zip(x.data()) {
        if xv <= 0.0 {
            *o = 0.0;
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Quadratic kernel: recompute forward + backward
// ---------------------------------------------------------------------------

/// Fused quadratic forward that also returns the per-row denominators
/// `Σ_j (q_i·k_j)²` (pre-ε) the backward needs.  Same masking and
/// streaming as
/// [`fused_quadratic_attention_spec`](super::fused_quadratic_attention_spec).
pub fn fused_quadratic_attention_spec_fwd_train(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
) -> (Mat, Vec<f32>) {
    fused_quadratic_attention_spec_fwd_train_par(q, k, v, spec, tile, 1)
}

/// One query row of the quadratic training forward; returns the row's
/// pre-ε denominator.  Shared by the serial and pooled entry points
/// (row math is row-local, so partitioning never changes results).
#[allow(clippy::too_many_arguments)]
fn quadratic_fwd_train_row(
    qrow: &[f32],
    kd: &[f32],
    vd: &[f32],
    d: usize,
    dv: usize,
    lim: usize,
    tile: usize,
    orow: &mut [f32],
    scores: &mut [f32],
) -> f32 {
    let mut den_i = 0.0f32;
    let mut t0 = 0;
    while t0 < lim {
        let tn = tile.min(lim - t0);
        let ktile = &kd[t0 * d..(t0 + tn) * d];
        crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
        for (j, &s) in scores[..tn].iter().enumerate() {
            let w = s * s;
            den_i += w;
            let vrow = &vd[(t0 + j) * dv..(t0 + j + 1) * dv];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += w * vv;
            }
        }
        t0 += tn;
    }
    let inv = 1.0 / (den_i + kernels::EPS);
    for o in orow.iter_mut() {
        *o *= inv;
    }
    den_i
}

/// [`fused_quadratic_attention_spec_fwd_train`] with query rows
/// partitioned across `threads` compute-pool tasks (0 = auto) —
/// bitwise identical to the serial walk at any thread count (row-local
/// math, like the softmax variant).
pub fn fused_quadratic_attention_spec_fwd_train_par(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    tile: usize,
    threads: usize,
) -> (Mat, Vec<f32>) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut out = Mat::zeros(nq, dv);
    let mut den = vec![0.0f32; nq];
    if nq == 0 || nk == 0 || dv == 0 {
        return (out, den);
    }
    let tile = kernels::resolve_tile(tile).min(nk);
    let (kd, vd) = (k.data(), v.data());
    let spans = query_spans(nq, nk, spec, threads);
    if spans.len() <= 1 {
        let mut scores = vec![0.0f32; tile];
        for i in 0..nq {
            let lim = spec.row_limit(i, nk);
            den[i] = quadratic_fwd_train_row(
                q.row(i),
                kd,
                vd,
                d,
                dv,
                lim,
                tile,
                out.row_mut(i),
                &mut scores,
            );
        }
        return (out, den);
    }
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(spans.len());
        let mut out_rest = out.data_mut();
        let mut den_rest = den.as_mut_slice();
        for &(row0, len) in &spans {
            let (o_c, o_t) = std::mem::take(&mut out_rest).split_at_mut(len * dv);
            out_rest = o_t;
            let (den_c, den_t) = std::mem::take(&mut den_rest).split_at_mut(len);
            den_rest = den_t;
            tasks.push(Box::new(move || {
                let mut scores = vec![0.0f32; tile];
                for r in 0..len {
                    let i = row0 + r;
                    let lim = spec.row_limit(i, nk);
                    den_c[r] = quadratic_fwd_train_row(
                        q.row(i),
                        kd,
                        vd,
                        d,
                        dv,
                        lim,
                        tile,
                        &mut o_c[r * dv..(r + 1) * dv],
                        &mut scores,
                    );
                }
            }));
        }
        crate::util::compute_pool::scope(tasks);
    }
    (out, den)
}

/// Recompute backward of the fused quadratic-kernel forward: same
/// tile streaming as [`fused_softmax_attention_spec_bwd`] with the
/// κ(q,k) = (q·k)² weight VJP (`dw_ij = dO_i·v_j / denε − δ_i / denε`,
/// `ds_ij = 2 s_ij dw_ij`, `denε = den_i + ε`).  O(tile) working set.
#[allow(clippy::too_many_arguments)]
pub fn fused_quadratic_attention_spec_bwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    den: &[f32],
    d_out: &Mat,
    tile: usize,
) -> (Mat, Mat, Mat) {
    fused_quadratic_attention_spec_bwd_par(q, k, v, spec, out, den, d_out, tile, 1)
}

/// One query row of the quadratic backward; `dk`/`dv_g` are flat
/// `(nk, d)` / `(nk, dv)` accumulation buffers (full matrices on the
/// serial path, span partials on the pooled path).
#[allow(clippy::too_many_arguments)]
fn quadratic_bwd_row(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    out: &Mat,
    d_out: &Mat,
    i: usize,
    lim: usize,
    inv: f32,
    tile: usize,
    scores: &mut [f32],
    dqrow: &mut [f32],
    dk: &mut [f32],
    dv_g: &mut [f32],
) {
    let d = q.cols();
    let dv = v.cols();
    let kd = k.data();
    let qrow = q.row(i);
    let dorow = d_out.row(i);
    let mut delta = 0.0f64;
    for (a, b) in dorow.iter().zip(out.row(i)) {
        delta += *a as f64 * *b as f64;
    }
    // dden_i = −(O_i · dO_i) / denε — the normalizer's pullback.
    let dden = -(delta as f32) * inv;
    dqrow.fill(0.0);
    let mut t0 = 0;
    while t0 < lim {
        let tn = tile.min(lim - t0);
        let ktile = &kd[t0 * d..(t0 + tn) * d];
        crate::tensor::micro::matmul_t_block(qrow, ktile, &mut scores[..tn], 1, d, tn);
        for j in 0..tn {
            let kj = t0 + j;
            let s = scores[j];
            let vrow = v.row(kj);
            let mut dp = 0.0f32;
            for (a, b) in dorow.iter().zip(vrow) {
                dp += a * b;
            }
            let dw = dp * inv + dden;
            let ds = 2.0 * s * dw;
            let w = s * s;
            let krow = k.row(kj);
            for (o, &x) in dqrow.iter_mut().zip(krow) {
                *o += ds * x;
            }
            let dkrow = &mut dk[kj * d..(kj + 1) * d];
            for (o, &x) in dkrow.iter_mut().zip(qrow) {
                *o += ds * x;
            }
            let dvrow = &mut dv_g[kj * dv..(kj + 1) * dv];
            for (o, &x) in dvrow.iter_mut().zip(dorow) {
                *o += w * inv * x;
            }
        }
        t0 += tn;
    }
}

/// [`fused_quadratic_attention_spec_bwd`] with query rows partitioned
/// across `threads` compute-pool tasks (0 = auto): span-local `dq`
/// (bitwise) plus per-span `dk`/`dv` partials reduced in fixed span
/// order, mirroring [`fused_softmax_attention_spec_bwd_par`].
#[allow(clippy::too_many_arguments)]
pub fn fused_quadratic_attention_spec_bwd_par(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    den: &[f32],
    d_out: &Mat,
    tile: usize,
    threads: usize,
) -> (Mat, Mat, Mat) {
    assert_eq!(q.cols(), k.cols(), "q/k head dims differ");
    assert_eq!(k.rows(), v.rows(), "key/value row mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    assert!(den.len() >= q.rows(), "saved denominators too short");
    let (nq, d) = q.shape();
    let nk = k.rows();
    let dv = v.cols();
    let mut dq = Mat::zeros(nq, d);
    let mut dk = Mat::zeros(nk, d);
    let mut dv_g = Mat::zeros(nk, dv);
    if nq == 0 || nk == 0 || dv == 0 {
        return (dq, dk, dv_g);
    }
    let tile = kernels::resolve_tile(tile).min(nk);
    let spans = query_spans(nq, nk, spec, threads);
    if spans.len() <= 1 {
        let mut scores = vec![0.0f32; tile];
        for i in 0..nq {
            let lim = spec.row_limit(i, nk);
            if lim == 0 {
                continue;
            }
            let inv = 1.0 / (den[i] + kernels::EPS);
            let (dk_flat, dv_flat) = (dk.data_mut(), dv_g.data_mut());
            quadratic_bwd_row(
                q,
                k,
                v,
                out,
                d_out,
                i,
                lim,
                inv,
                tile,
                &mut scores,
                dq.row_mut(i),
                dk_flat,
                dv_flat,
            );
        }
        return (dq, dk, dv_g);
    }
    let mut dk_parts: Vec<Vec<f32>> = spans.iter().map(|_| vec![0.0f32; nk * d]).collect();
    let mut dv_parts: Vec<Vec<f32>> = spans.iter().map(|_| vec![0.0f32; nk * dv]).collect();
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(spans.len());
        let mut dq_rest = dq.data_mut();
        for (&(row0, len), (dk_p, dv_p)) in
            spans.iter().zip(dk_parts.iter_mut().zip(dv_parts.iter_mut()))
        {
            let (dq_c, dq_t) = std::mem::take(&mut dq_rest).split_at_mut(len * d);
            dq_rest = dq_t;
            tasks.push(Box::new(move || {
                let mut scores = vec![0.0f32; tile];
                for r in 0..len {
                    let i = row0 + r;
                    let lim = spec.row_limit(i, nk);
                    if lim == 0 {
                        continue;
                    }
                    let inv = 1.0 / (den[i] + kernels::EPS);
                    quadratic_bwd_row(
                        q,
                        k,
                        v,
                        out,
                        d_out,
                        i,
                        lim,
                        inv,
                        tile,
                        &mut scores,
                        &mut dq_c[r * d..(r + 1) * d],
                        dk_p,
                        dv_p,
                    );
                }
            }));
        }
        crate::util::compute_pool::scope(tasks);
    }
    for dk_p in &dk_parts {
        for (a, b) in dk.data_mut().iter_mut().zip(dk_p) {
            *a += b;
        }
    }
    for dv_p in &dv_parts {
        for (a, b) in dv_g.data_mut().iter_mut().zip(dv_p) {
            *a += b;
        }
    }
    (dq, dk, dv_g)
}

// ---------------------------------------------------------------------------
// Performer: projected-feature chain rule
// ---------------------------------------------------------------------------

/// Chain rule through the Performer feature map
/// ([`performer_features`](super::performer_features)):
/// `φ(x)_ij = m^{-1/2}·cexp(u_ij − ‖x_i·d^{-1/4}‖²/2)` with
/// `u = (x·d^{-1/4})·Ω`.  The projection `Ω` is a fixed random matrix
/// (never trained), so only `dx` comes back:
///
/// ```text
/// du_ij  = dφ_ij·φ_ij      (cexp' = cexp inside the clamp, 0 at saturation)
/// dsq_i  = −Σ_j du_ij
/// dx_i   = d^{-1/4}·(du_i·Ωᵀ + dsq_i·x_i·d^{-1/4})
/// ```
pub fn performer_feature_bwd(x: &Mat, phi: &Mat, d_phi: &Mat, proj: &Mat) -> Mat {
    let (n, d) = x.shape();
    let m = proj.cols();
    assert_eq!(proj.rows(), d, "projection rows must match the head dim");
    assert_eq!(phi.shape(), (n, m), "x/phi shape mismatch");
    assert_eq!(d_phi.shape(), (n, m), "x/d_phi shape mismatch");
    let dscale = 1.0 / (d as f32).powf(0.25);
    let xs = x.scale(dscale);
    // Recompute the clamp arguments u_ij − sq_i to gate saturation.
    let u = xs.matmul(proj);
    let mut du = Mat::zeros(n, m);
    let mut dsq = vec![0.0f32; n];
    for i in 0..n {
        let mut sq = 0.0f32;
        for &a in xs.row(i) {
            sq += 0.5 * a * a;
        }
        let (urow, prow, dprow) = (u.row(i), phi.row(i), d_phi.row(i));
        let durow = du.row_mut(i);
        for j in 0..m {
            if (urow[j] - sq).abs() < EXP_CLAMP {
                let g = dprow[j] * prow[j];
                durow[j] = g;
                dsq[i] -= g;
            }
        }
    }
    let mut dx = du.matmul_t(proj);
    for i in 0..n {
        let g = dsq[i];
        for (o, &xv) in dx.row_mut(i).iter_mut().zip(xs.row(i)) {
            *o = (*o + g * xv) * dscale;
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Block-diagonal tiles: per-tile fused softmax recompute fwd/bwd
// ---------------------------------------------------------------------------

/// Copy rows `[start, start+len)` of `m` into a fresh matrix (the
/// per-tile operand views for the block-diagonal kernels).
fn slice_rows(m: &Mat, start: usize, len: usize) -> Mat {
    let c = m.cols();
    Mat::from_vec(len, c, m.data()[start * c..(start + len) * c].to_vec())
}

/// The global [`AttnSpec`] restricted to the diagonal tile at row/key
/// offset `b0`: keys shift down by `b0` (global
/// `row_limit(b0+i) − b0` equals the tile-local `row_limit(i)` for the
/// causal and `key_len` masks alike), and the scale is pinned to the
/// resolved global value so a tile can never re-derive it from a
/// different width.
fn tile_spec(spec: &AttnSpec, b0: usize, d: usize) -> AttnSpec {
    AttnSpec {
        causal: spec.causal,
        key_len: spec.key_len.map(|kl| kl.saturating_sub(b0)),
        scale: Some(spec.resolve_scale(d)),
    }
}

/// One diagonal tile of the block-diagonal training forward: the fused
/// softmax training forward on the tile's row slice under its local
/// spec, written into the caller's per-tile output/stat windows.
#[allow(clippy::too_many_arguments)]
fn blockdiag_tile_fwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    b0: usize,
    block: usize,
    tile: usize,
    o_c: &mut [f32],
    m_c: &mut [f32],
    l_c: &mut [f32],
) {
    let qt = slice_rows(q, b0, block);
    let kt = slice_rows(k, b0, block);
    let vt = slice_rows(v, b0, block);
    let ts = tile_spec(spec, b0, q.cols());
    let (ot, mt, lt) = fused_softmax_attention_spec_fwd_train(&qt, &kt, &vt, &ts, tile);
    o_c.copy_from_slice(ot.data());
    m_c.copy_from_slice(&mt);
    l_c.copy_from_slice(&lt);
}

/// Training forward of
/// [`blockdiag_attention_spec`](super::blockdiag_attention_spec): each
/// diagonal `block`×`block` softmax tile runs the fused training
/// forward under its tile-local spec (values agree with the inference
/// kernel to streaming tolerance), and the per-row online stats are
/// concatenated in tile order — `(out, row_max, row_sum)` — so the
/// backward can reuse the flash-style recompute tile by tile.
/// Requires `block` to divide `n` (the inference kernel's contract).
pub fn blockdiag_attention_spec_fwd_train(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    block: usize,
    tile: usize,
) -> (Mat, Vec<f32>, Vec<f32>) {
    blockdiag_attention_spec_fwd_train_par(q, k, v, spec, block, tile, 1)
}

/// [`blockdiag_attention_spec_fwd_train`] with the diagonal tiles
/// spread across `threads` compute-pool tasks (0 = auto).  Tiles are
/// fully independent (disjoint row ranges, serial math inside), so the
/// result is bitwise identical at any thread count.
pub fn blockdiag_attention_spec_fwd_train_par(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    block: usize,
    tile: usize,
    threads: usize,
) -> (Mat, Vec<f32>, Vec<f32>) {
    let (n, d) = q.shape();
    assert!(block > 0 && n % block == 0, "N must divide the block size");
    assert_eq!(k.shape(), (n, d), "blockdiag requires aligned q/k");
    assert_eq!(v.rows(), n, "key/value row mismatch");
    let dv = v.cols();
    let mut out = Mat::zeros(n, dv);
    let mut row_max = vec![f32::NEG_INFINITY; n];
    let mut row_sum = vec![0.0f32; n];
    if n == 0 || dv == 0 {
        return (out, row_max, row_sum);
    }
    let n_tiles = n / block;
    let t = crate::tensor::resolve_threads(threads).min(n_tiles);
    if t <= 1 {
        for ti in 0..n_tiles {
            let b0 = ti * block;
            blockdiag_tile_fwd(
                q,
                k,
                v,
                spec,
                b0,
                block,
                tile,
                &mut out.data_mut()[b0 * dv..(b0 + block) * dv],
                &mut row_max[b0..b0 + block],
                &mut row_sum[b0..b0 + block],
            );
        }
        return (out, row_max, row_sum);
    }
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n_tiles);
        let mut o_rest = out.data_mut();
        let mut m_rest = row_max.as_mut_slice();
        let mut l_rest = row_sum.as_mut_slice();
        for ti in 0..n_tiles {
            let (o_c, o_t) = std::mem::take(&mut o_rest).split_at_mut(block * dv);
            o_rest = o_t;
            let (m_c, m_t) = std::mem::take(&mut m_rest).split_at_mut(block);
            m_rest = m_t;
            let (l_c, l_t) = std::mem::take(&mut l_rest).split_at_mut(block);
            l_rest = l_t;
            tasks.push(Box::new(move || {
                blockdiag_tile_fwd(q, k, v, spec, ti * block, block, tile, o_c, m_c, l_c);
            }));
        }
        crate::util::compute_pool::scope(tasks);
    }
    (out, row_max, row_sum)
}

/// One diagonal tile of the block-diagonal backward: the fused softmax
/// recompute backward on the tile's slices, written into the caller's
/// per-tile `dq`/`dk`/`dv` windows.
#[allow(clippy::too_many_arguments)]
fn blockdiag_tile_bwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    row_max: &[f32],
    row_sum: &[f32],
    d_out: &Mat,
    b0: usize,
    block: usize,
    tile: usize,
    dq_c: &mut [f32],
    dk_c: &mut [f32],
    dv_c: &mut [f32],
) {
    let qt = slice_rows(q, b0, block);
    let kt = slice_rows(k, b0, block);
    let vt = slice_rows(v, b0, block);
    let ot = slice_rows(out, b0, block);
    let dot = slice_rows(d_out, b0, block);
    let ts = tile_spec(spec, b0, q.cols());
    let (dqt, dkt, dvt) = fused_softmax_attention_spec_bwd(
        &qt,
        &kt,
        &vt,
        &ts,
        &ot,
        &row_max[b0..b0 + block],
        &row_sum[b0..b0 + block],
        &dot,
        tile,
    );
    dq_c.copy_from_slice(dqt.data());
    dk_c.copy_from_slice(dkt.data());
    dv_c.copy_from_slice(dvt.data());
}

/// Backward of [`blockdiag_attention_spec_fwd_train`]: per diagonal
/// tile, the flash-style recompute backward under the tile-local spec;
/// `(dq, dk, dv)` assemble from the tiles' disjoint row ranges.  Fully
/// masked rows (`row_sum == 0`) contribute nothing, exactly like the
/// fused softmax backward they delegate to.
#[allow(clippy::too_many_arguments)]
pub fn blockdiag_attention_spec_bwd(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    row_max: &[f32],
    row_sum: &[f32],
    d_out: &Mat,
    block: usize,
    tile: usize,
) -> (Mat, Mat, Mat) {
    blockdiag_attention_spec_bwd_par(q, k, v, spec, out, row_max, row_sum, d_out, block, tile, 1)
}

/// [`blockdiag_attention_spec_bwd`] with the diagonal tiles spread
/// across `threads` compute-pool tasks (0 = auto) — bitwise identical
/// at any thread count (tiles write disjoint gradient rows).
#[allow(clippy::too_many_arguments)]
pub fn blockdiag_attention_spec_bwd_par(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    spec: &AttnSpec,
    out: &Mat,
    row_max: &[f32],
    row_sum: &[f32],
    d_out: &Mat,
    block: usize,
    tile: usize,
    threads: usize,
) -> (Mat, Mat, Mat) {
    let (n, d) = q.shape();
    assert!(block > 0 && n % block == 0, "N must divide the block size");
    assert_eq!(k.shape(), (n, d), "blockdiag requires aligned q/k");
    assert_eq!(v.rows(), n, "key/value row mismatch");
    assert_eq!(out.shape(), d_out.shape(), "out/d_out shape mismatch");
    assert!(row_max.len() >= n && row_sum.len() >= n, "saved stats too short");
    let dv = v.cols();
    let mut dq = Mat::zeros(n, d);
    let mut dk = Mat::zeros(n, d);
    let mut dv_g = Mat::zeros(n, dv);
    if n == 0 || dv == 0 {
        return (dq, dk, dv_g);
    }
    let n_tiles = n / block;
    let t = crate::tensor::resolve_threads(threads).min(n_tiles);
    if t <= 1 {
        for ti in 0..n_tiles {
            let b0 = ti * block;
            let (dq_f, dk_f, dv_f) = (dq.data_mut(), dk.data_mut(), dv_g.data_mut());
            blockdiag_tile_bwd(
                q,
                k,
                v,
                spec,
                out,
                row_max,
                row_sum,
                d_out,
                b0,
                block,
                tile,
                &mut dq_f[b0 * d..(b0 + block) * d],
                &mut dk_f[b0 * d..(b0 + block) * d],
                &mut dv_f[b0 * dv..(b0 + block) * dv],
            );
        }
        return (dq, dk, dv_g);
    }
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n_tiles);
        let mut dq_rest = dq.data_mut();
        let mut dk_rest = dk.data_mut();
        let mut dv_rest = dv_g.data_mut();
        for ti in 0..n_tiles {
            let (dq_c, dq_t) = std::mem::take(&mut dq_rest).split_at_mut(block * d);
            dq_rest = dq_t;
            let (dk_c, dk_t) = std::mem::take(&mut dk_rest).split_at_mut(block * d);
            dk_rest = dk_t;
            let (dv_c, dv_t) = std::mem::take(&mut dv_rest).split_at_mut(block * dv);
            dv_rest = dv_t;
            tasks.push(Box::new(move || {
                blockdiag_tile_bwd(
                    q,
                    k,
                    v,
                    spec,
                    out,
                    row_max,
                    row_sum,
                    d_out,
                    ti * block,
                    block,
                    tile,
                    dq_c,
                    dk_c,
                    dv_c,
                );
            }));
        }
        crate::util::compute_pool::scope(tasks);
    }
    (dq, dk, dv_g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::kernels::{
        fused_quadratic_attention_spec, fused_softmax_attention_spec, lln_features,
    };
    use crate::rng::Pcg64;

    fn probe(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        crate::attention::gaussian_qkv(n, d, 0.8, 0.8, &mut rng)
    }

    #[test]
    fn fwd_train_matches_fused_forward_under_specs() {
        let (q, k, v) = probe(48, 12, 1);
        for spec in [
            AttnSpec::FULL,
            AttnSpec::CAUSAL,
            AttnSpec::causal_padded(20),
            AttnSpec::padded(0),
            AttnSpec { scale: Some(0.2), ..AttnSpec::FULL },
        ] {
            for tile in [1usize, 7, 0, 200] {
                let fused = fused_softmax_attention_spec(&q, &k, &v, &spec, tile, 0, 1);
                let (out, m, l) = fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
                let err = out.max_abs_diff(&fused);
                assert!(err < 1e-5, "{spec:?} tile={tile}: {err}");
                assert_eq!(m.len(), 48);
                assert_eq!(l.len(), 48);
            }
        }
    }

    #[test]
    fn fused_softmax_backward_matches_dense_reference() {
        let (q, k, v) = probe(40, 10, 2);
        let mut rng = Pcg64::seed(3);
        let d_out = Mat::gaussian(40, 10, 1.0, &mut rng);
        for spec in [AttnSpec::FULL, AttnSpec::CAUSAL, AttnSpec::causal_padded(17)] {
            for tile in [1usize, 9, 0] {
                let (out, m, l) = fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, tile);
                let (dq, dk, dv) =
                    fused_softmax_attention_spec_bwd(&q, &k, &v, &spec, &out, &m, &l, &d_out, tile);
                let (dq2, dk2, dv2) = softmax_attention_spec_bwd_dense(&q, &k, &v, &spec, &d_out);
                assert!(dq.max_abs_diff(&dq2) < 1e-4, "{spec:?} tile={tile} dq");
                assert!(dk.max_abs_diff(&dk2) < 1e-4, "{spec:?} tile={tile} dk");
                assert!(dv.max_abs_diff(&dv2) < 1e-4, "{spec:?} tile={tile} dv");
            }
        }
    }

    #[test]
    fn quadratic_fwd_train_matches_fused_forward() {
        let (q, k, v) = probe(36, 8, 4);
        for spec in [AttnSpec::FULL, AttnSpec::CAUSAL, AttnSpec::padded(11)] {
            let fused = fused_quadratic_attention_spec(&q, &k, &v, &spec, 13, 0, 1);
            let (out, den) = fused_quadratic_attention_spec_fwd_train(&q, &k, &v, &spec, 13);
            assert!(out.max_abs_diff(&fused) < 1e-4, "{spec:?}");
            assert!(den.iter().all(|x| x.is_finite() && *x >= 0.0));
        }
    }

    #[test]
    fn linear_backward_zeroes_dead_key_rows() {
        let (q, k, v) = probe(32, 8, 5);
        let pq = lln_features(&q, 1.1);
        let pk = lln_features(&k, 1.1);
        let mut rng = Pcg64::seed(6);
        let d_out = Mat::gaussian(32, 8, 1.0, &mut rng);
        for spec in [AttnSpec::causal_padded(10), AttnSpec::padded(10)] {
            let out = crate::attention::linear_attention_spec(&pq, &pk, &v, &spec, 7, 1);
            let (dpq, dpk, dv) = linear_attention_spec_bwd(&pq, &pk, &v, &spec, &out, &d_out);
            assert_eq!(dpq.shape(), pq.shape());
            for j in 10..32 {
                assert!(dpk.row(j).iter().all(|&x| x == 0.0), "{spec:?}: dead dphi_k row {j}");
                assert!(dv.row(j).iter().all(|&x| x == 0.0), "{spec:?}: dead dv row {j}");
            }
        }
    }

    #[test]
    fn lln_feature_chain_rule_saturates_to_zero() {
        let x = Mat::from_vec(1, 3, vec![0.5, 40.0, -40.0]);
        let phi = lln_features(&x, 1.0);
        let d_phi = Mat::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        let (dx, ds) = lln_feature_bwd(&x, &phi, &d_phi, 1.0);
        // In-range entry: dφ/dx = φ.
        assert!((dx.get(0, 0) - phi.get(0, 0)).abs() < 1e-6);
        // Saturated entries: exactly zero.
        assert_eq!(dx.get(0, 1), 0.0);
        assert_eq!(dx.get(0, 2), 0.0);
        // dα only sees the live entry: x·φ·dφ = 0.5·e^0.5.
        assert!((ds - 0.5 * 0.5f32.exp()).abs() < 1e-5);
    }

    #[test]
    fn backward_kernels_handle_degenerate_shapes() {
        let q = Mat::zeros(0, 4);
        let k = Mat::zeros(3, 4);
        let v = Mat::zeros(3, 2);
        let out = Mat::zeros(0, 2);
        let (dq, dk, dv) = fused_softmax_attention_spec_bwd(
            &q,
            &k,
            &v,
            &AttnSpec::FULL,
            &out,
            &[],
            &[],
            &out,
            0,
        );
        assert_eq!(dq.shape(), (0, 4));
        assert_eq!(dk.shape(), (3, 4));
        assert_eq!(dv.shape(), (3, 2));
    }

    #[test]
    fn blockdiag_fwd_train_matches_inference_kernel_under_specs() {
        let (q, k, v) = probe(48, 12, 8);
        for spec in [
            AttnSpec::FULL,
            AttnSpec::CAUSAL,
            AttnSpec::causal_padded(20),
            AttnSpec::padded(30),
            AttnSpec { scale: Some(0.3), ..AttnSpec::FULL },
        ] {
            let reference = crate::attention::blockdiag_attention_spec(&q, &k, &v, 16, &spec);
            let (out, m, l) = blockdiag_attention_spec_fwd_train(&q, &k, &v, &spec, 16, 0);
            let err = out.max_abs_diff(&reference);
            assert!(err < 1e-5, "{spec:?}: {err}");
            assert_eq!(m.len(), 48);
            assert_eq!(l.len(), 48);
            // Pooled path: bitwise identical (disjoint tiles).
            let (out_p, m_p, l_p) =
                blockdiag_attention_spec_fwd_train_par(&q, &k, &v, &spec, 16, 0, 4);
            assert_eq!(out.data(), out_p.data());
            assert_eq!(m, m_p);
            assert_eq!(l, l_p);
        }
    }

    #[test]
    fn blockdiag_backward_is_blockdiagonal_and_thread_invariant() {
        let (q, k, v) = probe(32, 8, 9);
        let mut rng = Pcg64::seed(10);
        let d_out = Mat::gaussian(32, 8, 1.0, &mut rng);
        for spec in [AttnSpec::FULL, AttnSpec::CAUSAL, AttnSpec::causal_padded(13)] {
            let (out, m, l) = blockdiag_attention_spec_fwd_train(&q, &k, &v, &spec, 8, 0);
            let (dq, dk, dv) =
                blockdiag_attention_spec_bwd(&q, &k, &v, &spec, &out, &m, &l, &d_out, 8, 0);
            assert!(dq.data().iter().all(|x| x.is_finite()));
            // Key rows masked dead by key_len get exact-zero gradients.
            if let Some(kl) = spec.key_len {
                for j in kl..32 {
                    assert!(dk.row(j).iter().all(|&x| x == 0.0), "{spec:?} dk row {j}");
                    assert!(dv.row(j).iter().all(|&x| x == 0.0), "{spec:?} dv row {j}");
                }
            }
            let (dq_p, dk_p, dv_p) = blockdiag_attention_spec_bwd_par(
                &q, &k, &v, &spec, &out, &m, &l, &d_out, 8, 0, 4,
            );
            assert_eq!(dq.data(), dq_p.data());
            assert_eq!(dk.data(), dk_p.data());
            assert_eq!(dv.data(), dv_p.data());
        }
    }

    #[test]
    fn performer_feature_chain_rule_saturates_to_zero() {
        use crate::attention::kernels::{performer_features, performer_projection};
        let proj = performer_projection(4, 6, 7);
        let mut rng = Pcg64::seed(11);
        let x = Mat::gaussian(5, 4, 0.8, &mut rng);
        let phi = performer_features(&x, &proj);
        let d_phi = Mat::gaussian(5, 6, 1.0, &mut rng);
        let dx = performer_feature_bwd(&x, &phi, &d_phi, &proj);
        assert_eq!(dx.shape(), (5, 4));
        assert!(dx.data().iter().all(|g| g.is_finite()));
        // A saturating input (huge norm drives every clamp argument out
        // of range) gets an exact-zero gradient.
        let hot = Mat::from_vec(1, 4, vec![50.0, -50.0, 50.0, -50.0]);
        let phi_hot = performer_features(&hot, &proj);
        let d_hot = Mat::from_vec(1, 6, vec![1.0; 6]);
        let dx_hot = performer_feature_bwd(&hot, &phi_hot, &d_hot, &proj);
        assert!(dx_hot.data().iter().all(|&g| g == 0.0), "{:?}", dx_hot.data());
    }

    #[test]
    fn fused_backward_long_causal_runs_in_tile_memory() {
        // The acceptance smoke: a causal fused backward at n=4096 never
        // touches an n×n buffer (working set is O(tile) by
        // construction) — this would OOM/crawl if it materialized
        // 4096² scores.
        let n = 4096;
        let mut rng = Pcg64::seed(7);
        let q = Mat::gaussian(n, 4, 0.8, &mut rng);
        let k = Mat::gaussian(n, 4, 0.8, &mut rng);
        let v = Mat::gaussian(n, 2, 1.0, &mut rng);
        let d_out = Mat::gaussian(n, 2, 1.0, &mut rng);
        let spec = AttnSpec::CAUSAL;
        let (out, m, l) = fused_softmax_attention_spec_fwd_train(&q, &k, &v, &spec, 256);
        let (dq, dk, dv) =
            fused_softmax_attention_spec_bwd(&q, &k, &v, &spec, &out, &m, &l, &d_out, 256);
        assert!(dq.data().iter().all(|x| x.is_finite()));
        assert!(dk.data().iter().all(|x| x.is_finite()));
        assert!(dv.data().iter().all(|x| x.is_finite()));
        // Row 0's softmax is over a single key (p = 1 whatever q_0 is),
        // so its query gradient must vanish.
        assert!(dq.row(0).iter().all(|&x| x.abs() < 1e-5), "{:?}", dq.row(0));
    }
}
