//! The thread-confined PJRT execution engine.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactSpec, IoSpec, Manifest};
use crate::tensor::Mat;

/// A host-side tensor moving across threads (what requests/batches carry).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![x] }
    }

    pub fn from_mat(m: &Mat) -> Self {
        HostTensor::F32 { shape: vec![m.rows(), m.cols()], data: m.data().to_vec() }
    }

    pub fn to_mat(&self) -> Result<Mat> {
        match self {
            HostTensor::F32 { shape, data } if shape.len() == 2 => {
                Ok(Mat::from_vec(shape[0], shape[1], data.clone()))
            }
            _ => bail!("not a 2-D f32 tensor: {:?}", self.shape()),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn first_f32(&self) -> Result<f32> {
        Ok(self.as_f32()?.first().copied().unwrap_or(0.0))
    }

    /// Build an xla literal with this tensor's shape/dtype.
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            HostTensor::F32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
                    .map_err(|e| anyhow!("literal f32 {shape:?}: {e:?}"))
            }
            HostTensor::I32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
                    .map_err(|e| anyhow!("literal i32 {shape:?}: {e:?}"))
            }
        }
    }

    /// Read a literal back into a host tensor using the manifest spec's
    /// shape (PJRT returns logical shapes; we trust the manifest).
    pub fn from_literal(lit: &Literal, spec: &IoSpec) -> Result<Self> {
        match spec.dtype.as_str() {
            "f32" => {
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("read f32: {e:?}"))?;
                Ok(HostTensor::F32 { shape: spec.shape.clone(), data })
            }
            "i32" => {
                let data = lit.to_vec::<i32>().map_err(|e| anyhow!("read i32: {e:?}"))?;
                Ok(HostTensor::I32 { shape: spec.shape.clone(), data })
            }
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// Thread-confined engine: PJRT CPU client + manifest + compiled-executable
/// cache.  Construct one per thread that needs to execute artifacts.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
}

impl Engine {
    pub fn new(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir: dir.to_path_buf(), manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.artifact(name)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.artifact(name)?.clone();
            let path = self.dir.join(&spec.file);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile a set of artifacts (worker warmup).
    pub fn warmup(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Execute by artifact name with host tensors; validates shapes and
    /// dtypes against the manifest and returns outputs in manifest order.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, ispec) in inputs.iter().zip(&spec.inputs) {
            if t.len() != ispec.elements() {
                bail!(
                    "{name}: input {} has {} elements, manifest says {:?}",
                    ispec.name,
                    t.len(),
                    ispec.shape
                );
            }
            literals.push(t.to_literal().with_context(|| format!("{name}: input {}", ispec.name))?);
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: {} outputs vs manifest {}", parts.len(), spec.outputs.len());
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                HostTensor::from_literal(lit, ospec)
                    .with_context(|| format!("{name}: output {}", ospec.name))
            })
            .collect()
    }

    /// Execute with pre-built literals, returning raw output literals
    /// (the training driver's and serving workers' zero-copy hot path).
    /// Accepts owned or borrowed literals so resident parameter sets can
    /// be passed by reference every call.
    pub fn execute_literals<L: std::borrow::Borrow<Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<L>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};
    use crate::rng::Pcg64;

    fn engine() -> Option<Engine> {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::new(&dir).expect("engine"))
    }

    #[test]
    fn host_tensor_round_trip_f32() {
        let t = HostTensor::F32 { shape: vec![2, 3], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let lit = t.to_literal().unwrap();
        let spec = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: "f32".into() };
        let back = HostTensor::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn host_tensor_round_trip_i32() {
        let t = HostTensor::I32 { shape: vec![4], data: vec![1, -2, 3, 7] };
        let lit = t.to_literal().unwrap();
        let spec = IoSpec { name: "x".into(), shape: vec![4], dtype: "i32".into() };
        let back = HostTensor::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_i32().unwrap(), t.as_i32().unwrap());
    }

    #[test]
    fn lln_micro_kernel_matches_native() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Pcg64::seed(42);
        let (n, d) = (256, 64);
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let v = Mat::gaussian(n, d, 1.0, &mut rng);
        let (alpha, beta) = (2.0f32, 2.0f32);
        let out = eng
            .execute(
                "attn_lln_n256",
                &[
                    HostTensor::from_mat(&q),
                    HostTensor::from_mat(&k),
                    HostTensor::from_mat(&v),
                    HostTensor::scalar_f32(alpha),
                    HostTensor::scalar_f32(beta),
                ],
            )
            .unwrap();
        let got = out[0].to_mat().unwrap();
        let want = crate::attention::lln_attention(&q, &k, &v, alpha, beta);
        let err = got.max_abs_diff(&want);
        assert!(err < 2e-3, "PJRT vs native mismatch: {err}");
    }

    #[test]
    fn softmax_micro_kernel_matches_native() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Pcg64::seed(43);
        let (n, d) = (256, 64);
        let q = Mat::gaussian(n, d, 1.0, &mut rng);
        let k = Mat::gaussian(n, d, 1.0, &mut rng);
        let v = Mat::gaussian(n, d, 1.0, &mut rng);
        let out = eng
            .execute(
                "attn_softmax_n256",
                &[HostTensor::from_mat(&q), HostTensor::from_mat(&k), HostTensor::from_mat(&v)],
            )
            .unwrap();
        let got = out[0].to_mat().unwrap();
        let want = crate::attention::softmax_attention(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 2e-3);
    }

    #[test]
    fn wrong_arity_rejected() {
        let Some(mut eng) = engine() else { return };
        let err = eng.execute("attn_softmax_n256", &[]).unwrap_err();
        assert!(format!("{err}").contains("inputs"));
    }

    #[test]
    fn wrong_shape_rejected() {
        let Some(mut eng) = engine() else { return };
        let bad = HostTensor::F32 { shape: vec![2, 2], data: vec![0.0; 4] };
        let err = eng.execute("attn_softmax_n256", &[bad.clone(), bad.clone(), bad]).unwrap_err();
        assert!(format!("{err}").contains("elements"));
    }
}
