//! Eigen-analysis substrate: the spectral-gap instrument (paper §3.2.2,
//! Thm 3.3) without an external linear-algebra crate.
//!
//! A stochastic matrix has lambda_1 = 1 with right eigenvector 1
//! (Perron–Frobenius); Wielandt deflation with the column-mean vector mu
//! (`P - 1 mu^T`) removes it, and power iteration on the deflated matrix
//! recovers |lambda_2|.  The gap is `1 - |lambda_2|` — the paper's
//! *unbiased attention concentration* measure.

use crate::tensor::{vec_ops, Mat};
use crate::rng::Pcg64;

/// Result of the second-eigenvalue estimation.
#[derive(Clone, Copy, Debug)]
pub struct SpectralResult {
    /// |lambda_2| of the stochastic matrix.
    pub lambda2_abs: f64,
    /// Spectral gap, 1 - |lambda_2|.
    pub gap: f64,
    /// Power-iteration steps actually used.
    pub iterations: usize,
    /// Final residual  ||Ax - lambda x|| / |lambda|.
    pub residual: f64,
}

/// Dominant |eigenvalue| of a general square matrix via power iteration
/// with periodic renormalization.  Uses a deterministic seeded start so
/// results are reproducible run to run.
pub fn power_iteration(a: &Mat, max_iters: usize, tol: f64, seed: u64) -> (f64, Vec<f32>, usize, f64) {
    assert_eq!(a.rows(), a.cols(), "power iteration needs a square matrix");
    let n = a.rows();
    let mut rng = Pcg64::seed(seed);
    let mut x: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
    let inv = 1.0 / vec_ops::norm(&x).max(1e-30);
    vec_ops::scale_inplace(&mut x, inv as f32);

    let mut lambda = 0.0f64;
    let mut iters = 0;
    let mut residual = f64::INFINITY;
    for it in 0..max_iters {
        iters = it + 1;
        let y = a.matvec(&x);
        let norm_y = vec_ops::norm(&y);
        if norm_y < 1e-30 {
            // x is (numerically) in the null space: dominant eigenvalue 0.
            return (0.0, x, iters, 0.0);
        }
        let new_lambda = vec_ops::dot(&y, &x); // Rayleigh quotient (x normalized)
        let mut y = y;
        let invn = 1.0 / norm_y;
        vec_ops::scale_inplace(&mut y, invn as f32);
        // Residual against the Rayleigh estimate.
        let ax = a.matvec(&y);
        let mut r = 0.0f64;
        let lam_y = vec_ops::dot(&ax, &y);
        for (axi, yi) in ax.iter().zip(&y) {
            let d = *axi as f64 - lam_y * *yi as f64;
            r += d * d;
        }
        residual = r.sqrt() / lam_y.abs().max(1e-12);
        let converged = (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1e-12);
        lambda = new_lambda;
        x = y;
        if converged && it > 2 {
            break;
        }
    }
    // Power iteration on a general (non-symmetric) matrix converges to
    // |lambda_max| of the symmetrized action along the iterate; the
    // Rayleigh quotient may be signed — magnitude is what we report.
    let y = a.matvec(&x);
    let mag = vec_ops::norm(&y) / vec_ops::norm(&x).max(1e-30);
    (mag, x, iters, residual)
}

/// |lambda_2| and spectral gap of a row-stochastic matrix (paper Thm 3.3).
pub fn spectral_gap(p: &Mat, max_iters: usize, tol: f64) -> SpectralResult {
    assert_eq!(p.rows(), p.cols());
    let n = p.rows();
    // mu = column means; deflated = P - 1 mu^T has eigenvalues {0, l2, ...}.
    let mu: Vec<f32> = p.col_sums().iter().map(|&s| s / n as f32).collect();
    let deflated = deflate_stochastic(p, &mu);
    let (lambda2, _v, iterations, residual) = power_iteration(&deflated, max_iters, tol, 0x5eed);
    let lambda2_abs = lambda2.abs().min(1.0);
    SpectralResult { lambda2_abs, gap: 1.0 - lambda2_abs, iterations, residual }
}

/// `P - 1 mu^T` (Wielandt deflation of lambda_1 = 1 for stochastic P).
pub fn deflate_stochastic(p: &Mat, mu: &[f32]) -> Mat {
    let n = p.rows();
    Mat::from_fn(n, n, |i, j| p.get(i, j) - mu[j])
}

/// Variance along the leading principal component of the deflated matrix
/// — Thm 3.3 says this equals lambda_2^2.  Exposed separately so the
/// fig. 2 experiment can verify the theorem numerically.
pub fn leading_pc_variance(p: &Mat, max_iters: usize, tol: f64) -> f64 {
    let n = p.rows();
    let mu: Vec<f32> = p.col_sums().iter().map(|&s| s / n as f32).collect();
    let d = deflate_stochastic(p, &mu);
    // Power iteration on the covariance action C x = D^T (D x): dominant
    // eigenvalue of D^T D = squared top singular value of D.
    let mut rng = Pcg64::seed(0xc0f);
    let mut x: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
    let inv = 1.0 / vec_ops::norm(&x).max(1e-30);
    vec_ops::scale_inplace(&mut x, inv as f32);
    let mut lambda = 0.0f64;
    for _ in 0..max_iters {
        let y = d.matvec(&x);
        let z = d.matvec_t(&y);
        let nz = vec_ops::norm(&z);
        if nz < 1e-30 {
            return 0.0;
        }
        let new_lambda = vec_ops::dot(&z, &x);
        let mut z = z;
        vec_ops::scale_inplace(&mut z, (1.0 / nz) as f32);
        let conv = (new_lambda - lambda).abs() <= tol * new_lambda.abs().max(1e-12);
        lambda = new_lambda;
        x = z;
        if conv {
            break;
        }
    }
    lambda.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_stochastic(n: usize, temp: f32, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        let mut p = Mat::gaussian(n, n, 1.0 / temp.max(1e-3), &mut rng);
        p.softmax_rows();
        p
    }

    #[test]
    fn power_iteration_diagonal() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.5]);
        let (lam, _, _, _) = power_iteration(&a, 200, 1e-12, 1);
        assert!((lam - 3.0).abs() < 1e-6, "{lam}");
    }

    #[test]
    fn uniform_matrix_gap_is_one() {
        // P = 1/n has lambda_2 = 0 => gap 1 (fully exploratory attention).
        let n = 32;
        let p = Mat::from_vec(n, n, vec![1.0 / n as f32; n * n]);
        let r = spectral_gap(&p, 200, 1e-10);
        assert!(r.lambda2_abs < 1e-4, "{r:?}");
        assert!((r.gap - 1.0).abs() < 1e-4);
    }

    #[test]
    fn identity_matrix_gap_is_zero() {
        // P = I is maximally concentrated-but-unbiased: lambda_2 = 1, gap 0.
        let p = Mat::eye(16);
        let r = spectral_gap(&p, 300, 1e-12);
        assert!(r.lambda2_abs > 0.999, "{r:?}");
        assert!(r.gap < 1e-3);
    }

    #[test]
    fn biased_matrix_has_large_gap() {
        // All rows concentrated on one column: rank-1, lambda_2 = 0.
        let n = 16;
        let p = Mat::from_fn(n, n, |_, j| if j == 3 { 1.0 } else { 0.0 });
        let r = spectral_gap(&p, 200, 1e-10);
        assert!(r.gap > 0.999, "{r:?}");
    }

    #[test]
    fn spectral_gap_always_in_unit_interval() {
        // Invariant: for any row-stochastic matrix, |lambda_2| and the
        // gap both live in [0, 1] (property-swept over temperatures).
        crate::testkit::check(24, |g| {
            let n = g.usize_in(4, 40);
            let temp = g.f32_in(0.1, 4.0);
            let seed = g.u64(0, 1_000_000);
            let p = {
                let mut rng = Pcg64::seed(seed);
                let mut p = Mat::gaussian(n, n, 1.0 / temp.max(1e-3), &mut rng);
                p.softmax_rows();
                p
            };
            let r = spectral_gap(&p, 300, 1e-8);
            crate::testkit::prop_assert(
                (0.0..=1.0).contains(&r.lambda2_abs),
                format!("lambda2 {} out of [0,1]", r.lambda2_abs),
            )?;
            crate::testkit::prop_assert(
                (0.0..=1.0).contains(&r.gap),
                format!("gap {} out of [0,1]", r.gap),
            )
        });
    }

    #[test]
    fn thm_3_3_lambda2_squared_equals_pc_variance() {
        for seed in [1u64, 2, 3] {
            let p = random_stochastic(48, 0.7, seed);
            let r = spectral_gap(&p, 2000, 1e-12);
            let pc_var = leading_pc_variance(&p, 2000, 1e-12);
            // lambda_2^2 ~= top singular value^2 of the deflated matrix.
            // Power iteration on a non-normal matrix gives |lambda_2| <=
            // sigma_max, so check the ordering + closeness band.
            assert!(
                r.lambda2_abs * r.lambda2_abs <= pc_var * 1.05 + 1e-9,
                "seed {seed}: l2^2={} pc={}",
                r.lambda2_abs * r.lambda2_abs,
                pc_var
            );
        }
    }

    #[test]
    fn gap_increases_with_temperature_for_unbiased() {
        // Thm 3.4 + Thm 3.3: hotter softmax (more uniform) => larger gap.
        let cold = random_stochastic(48, 0.25, 9);
        let hot = random_stochastic(48, 4.0, 9);
        let g_cold = spectral_gap(&cold, 2000, 1e-10).gap;
        let g_hot = spectral_gap(&hot, 2000, 1e-10).gap;
        assert!(g_hot > g_cold, "hot={g_hot} cold={g_cold}");
    }

    #[test]
    fn deflated_matrix_is_doubly_centered() {
        let p = random_stochastic(24, 1.0, 4);
        let mu: Vec<f32> = p.col_sums().iter().map(|&s| s / 24.0).collect();
        let d = deflate_stochastic(&p, &mu);
        for s in d.row_sums() {
            assert!(s.abs() < 1e-4, "row sum {s}");
        }
        for s in d.col_sums() {
            assert!(s.abs() < 1e-4, "col sum {s}");
        }
    }
}
