//! The attention kernels themselves: outputs (O(N) formulations where the
//! method allows) and explicit stochastic matrices (for analysis).
//!
//! Numerics mirror python/compile/kernels/ref.py exactly: same clamping,
//! same eps, same landmark/feature constructions — integration tests
//! assert closeness against the PJRT-executed artifacts.

use super::EXP_CLAMP;
use crate::rng::Pcg64;
use crate::tensor::Mat;

const EPS: f32 = 1e-6;

#[inline]
fn clamped_exp(x: f32) -> f32 {
    x.clamp(-EXP_CLAMP, EXP_CLAMP).exp()
}

// ---------------------------------------------------------------------------
// Softmax attention (paper eq. 1)
// ---------------------------------------------------------------------------

/// Full softmax attention output; O(N^2) time and memory.
pub fn softmax_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    softmax_attention_matrix(q, k).matmul(v)
}

/// The stochastic matrix P^(SM) (paper eq. 6).
pub fn softmax_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    let d = q.cols();
    let mut scores = q.matmul_t(k);
    let scale = 1.0 / (d as f32).sqrt();
    scores.map_inplace(|x| x * scale);
    scores.softmax_rows();
    scores
}

// ---------------------------------------------------------------------------
// Generic linearized attention (paper eq. 4)
// ---------------------------------------------------------------------------

/// O(N m d) linear attention from explicit feature maps.
pub fn linear_attention(phi_q: &Mat, phi_k: &Mat, v: &Mat) -> Mat {
    let kv = phi_k.transpose().matmul(v); // (m, dv)
    let z = phi_k.col_sums(); // (m,)
    let num = phi_q.matmul(&kv); // (n, dv)
    let den = phi_q.matvec(&z); // (n,)
    let mut out = num;
    for i in 0..out.rows() {
        let inv = 1.0 / (den[i] + EPS);
        for x in out.row_mut(i) {
            *x *= inv;
        }
    }
    out
}

/// Explicit N x N stochastic matrix of a linearized attention.
pub fn linear_attention_matrix(phi_q: &Mat, phi_k: &Mat) -> Mat {
    let mut p = phi_q.matmul_t(phi_k);
    p.normalize_rows(EPS);
    p
}

// ---------------------------------------------------------------------------
// LLN attention (paper eq. 8-9)
// ---------------------------------------------------------------------------

pub fn lln_features(x: &Mat, scale: f32) -> Mat {
    x.map(|v| clamped_exp(scale * v))
}

pub fn lln_attention(q: &Mat, k: &Mat, v: &Mat, alpha: f32, beta: f32) -> Mat {
    linear_attention(&lln_features(q, alpha), &lln_features(k, beta), v)
}

pub fn lln_attention_matrix(q: &Mat, k: &Mat, alpha: f32, beta: f32) -> Mat {
    linear_attention_matrix(&lln_features(q, alpha), &lln_features(k, beta))
}

// ---------------------------------------------------------------------------
// ELU / ReLU / quadratic kernels
// ---------------------------------------------------------------------------

pub fn elu_features(x: &Mat) -> Mat {
    x.map(|v| if v > 0.0 { v + 1.0 } else { v.exp() })
}

pub fn elu_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    linear_attention(&elu_features(q), &elu_features(k), v)
}

pub fn elu_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    linear_attention_matrix(&elu_features(q), &elu_features(k))
}

pub fn relu_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    let f = |m: &Mat| m.map(|v| v.max(0.0));
    linear_attention_matrix(&f(q), &f(k))
}

/// kappa(q, k) = (q . k)^2 — the fig. 2 "quadratic kernel" comparator.
pub fn quadratic_attention_matrix(q: &Mat, k: &Mat) -> Mat {
    let mut p = q.matmul_t(k);
    p.map_inplace(|x| x * x);
    p.normalize_rows(EPS);
    p
}

// ---------------------------------------------------------------------------
// Performer (FAVOR+ positive features)
// ---------------------------------------------------------------------------

/// Deterministic Gaussian projection for Performer features.
pub fn performer_projection(d: usize, m: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed(seed);
    Mat::gaussian(d, m, 1.0, &mut rng)
}

pub fn performer_features(x: &Mat, proj: &Mat) -> Mat {
    let d = x.cols();
    let m = proj.cols();
    let scale = 1.0 / (m as f32).sqrt();
    let dscale = 1.0 / (d as f32).powf(0.25);
    let xs = x.scale(dscale);
    let u = xs.matmul(proj); // (n, m)
    let mut out = Mat::zeros(x.rows(), m);
    for i in 0..x.rows() {
        let sq: f32 = xs.row(i).iter().map(|&a| a * a).sum::<f32>() * 0.5;
        for j in 0..m {
            out.set(i, j, scale * clamped_exp(u.get(i, j) - sq));
        }
    }
    out
}

pub fn performer_attention(q: &Mat, k: &Mat, v: &Mat, proj: &Mat) -> Mat {
    linear_attention(&performer_features(q, proj), &performer_features(k, proj), v)
}

pub fn performer_attention_matrix(q: &Mat, k: &Mat, proj: &Mat) -> Mat {
    linear_attention_matrix(&performer_features(q, proj), &performer_features(k, proj))
}

// ---------------------------------------------------------------------------
// Nystromformer (segment-mean landmarks + Newton-Schulz pinv)
// ---------------------------------------------------------------------------

fn segment_means(x: &Mat, m: usize) -> Mat {
    let n = x.rows();
    let seg = n / m;
    let mut out = Mat::zeros(m, x.cols());
    for s in 0..m {
        for i in s * seg..(s + 1) * seg {
            for (o, &val) in out.row_mut(s).iter_mut().zip(x.row(i)) {
                *o += val;
            }
        }
        let inv = 1.0 / seg as f32;
        for o in out.row_mut(s) {
            *o *= inv;
        }
    }
    out
}

fn softmax_scores(a: &Mat, b: &Mat, scale: f32) -> Mat {
    let mut s = a.matmul_t(b);
    s.map_inplace(|x| x * scale);
    s.softmax_rows();
    s
}

/// Newton–Schulz iterative pseudo-inverse (matches ref.py, 12 iters).
pub fn newton_schulz_pinv(a: &Mat, iters: usize) -> Mat {
    let n = a.rows();
    let max_col: f32 = (0..n)
        .map(|j| (0..n).map(|i| a.get(i, j).abs()).sum::<f32>())
        .fold(0.0, f32::max);
    let max_row: f32 = (0..n).map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>()).fold(0.0, f32::max);
    let mut z = a.transpose().scale(1.0 / (max_col * max_row).max(1e-12));
    let ident = Mat::eye(n);
    for _ in 0..iters {
        let az = a.matmul(&z);
        // z <- z (13 I - az (15 I - az (7 I - az))) / 4
        let t1 = ident.scale(7.0).sub(&az);
        let t2 = ident.scale(15.0).sub(&az.matmul(&t1));
        let t3 = ident.scale(13.0).sub(&az.matmul(&t2));
        z = z.matmul(&t3).scale(0.25);
    }
    z
}

pub fn nystrom_attention(q: &Mat, k: &Mat, v: &Mat, landmarks: usize) -> Mat {
    let n = q.rows();
    let m = landmarks.min(n);
    assert!(n % m == 0, "N must divide landmark count");
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let q_l = segment_means(q, m);
    let k_l = segment_means(k, m);
    let f = softmax_scores(q, &k_l, scale); // (n, m)
    let a = softmax_scores(&q_l, &k_l, scale); // (m, m)
    let b = softmax_scores(&q_l, k, scale); // (m, n)
    f.matmul(&newton_schulz_pinv(&a, 12).matmul(&b.matmul(v)))
}

// ---------------------------------------------------------------------------
// Block-diagonal + LLN+Diag (paper sec. 4.2)
// ---------------------------------------------------------------------------

pub fn blockdiag_attention(q: &Mat, k: &Mat, v: &Mat, block: usize) -> Mat {
    let (n, d) = q.shape();
    assert!(n % block == 0, "N must divide block size");
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Mat::zeros(n, v.cols());
    for b0 in (0..n).step_by(block) {
        // scores over the diagonal tile only
        let mut s = Mat::zeros(block, block);
        for i in 0..block {
            for j in 0..block {
                let mut acc = 0.0f32;
                for t in 0..d {
                    acc += q.get(b0 + i, t) * k.get(b0 + j, t);
                }
                s.set(i, j, acc * scale);
            }
        }
        s.softmax_rows();
        for i in 0..block {
            for j in 0..block {
                let p = s.get(i, j);
                for t in 0..v.cols() {
                    let cur = out.get(b0 + i, t);
                    out.set(b0 + i, t, cur + p * v.get(b0 + j, t));
                }
            }
        }
    }
    out
}

pub fn lln_diag_attention(q: &Mat, k: &Mat, v: &Mat, alpha: f32, beta: f32, block: usize) -> Mat {
    let long = lln_attention(q, k, v, alpha, beta);
    let short = blockdiag_attention(q, k, v, block);
    let mut out = long;
    for (o, s) in out.data_mut().iter_mut().zip(short.data()) {
        *o = 0.5 * (*o + s);
    }
    out
}

// ---------------------------------------------------------------------------
// Linformer (projection baseline)
// ---------------------------------------------------------------------------

pub fn linformer_attention(q: &Mat, k: &Mat, v: &Mat, e: &Mat, f: &Mat) -> Mat {
    // e, f: (n, kproj); project keys/values along the sequence axis.
    let kp = e.transpose().matmul(k); // (kproj, d)
    let vp = f.transpose().matmul(v); // (kproj, dv)
    softmax_attention(q, &kp, &vp)
}

/// Dispatch: stochastic matrix for any method (fig. 2 sweeps).
pub fn attention_matrix(
    method: super::Method,
    q: &Mat,
    k: &Mat,
    alpha: f32,
    beta: f32,
) -> Mat {
    use super::Method::*;
    match method {
        Softmax => softmax_attention_matrix(q, k),
        Lln | LlnDiag => lln_attention_matrix(q, k, alpha, beta),
        Elu => elu_attention_matrix(q, k),
        Relu => relu_attention_matrix(q, k),
        Quadratic => quadratic_attention_matrix(q, k),
        Performer => {
            let proj = performer_projection(q.cols(), q.cols(), 7);
            performer_attention_matrix(q, k, &proj)
        }
        Nystrom | BlockDiag | Linformer => {
            panic!("no dense stochastic-matrix form for {method:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::gaussian_qkv;
    use crate::rng::Pcg64;

    fn probe(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        gaussian_qkv(n, d, 1.0, 1.0, &mut rng)
    }

    #[test]
    fn softmax_matrix_is_stochastic() {
        let (q, k, _) = probe(64, 32, 1);
        assert!(softmax_attention_matrix(&q, &k).is_stochastic(1e-4));
    }

    #[test]
    fn lln_matrix_is_stochastic() {
        let (q, k, _) = probe(64, 32, 2);
        assert!(lln_attention_matrix(&q, &k, 2.0, 2.0).is_stochastic(1e-4));
    }

    #[test]
    fn linear_attention_matches_explicit_matrix_route() {
        let (q, k, v) = probe(64, 16, 3);
        let pq = lln_features(&q, 1.5);
        let pk = lln_features(&k, 1.5);
        let fast = linear_attention(&pq, &pk, &v);
        let slow = linear_attention_matrix(&pq, &pk).matmul(&v);
        assert!(fast.max_abs_diff(&slow) < 1e-3, "{}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn softmax_output_in_value_hull() {
        let (q, k, v) = probe(48, 16, 4);
        let out = softmax_attention(&q, &k, &v);
        let vmax = v.data().iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.data().iter().cloned().fold(f32::MAX, f32::min);
        assert!(out.data().iter().all(|&x| x <= vmax + 1e-4 && x >= vmin - 1e-4));
    }

    #[test]
    fn blockdiag_matches_softmax_when_block_is_full() {
        let (q, k, v) = probe(32, 16, 5);
        let full = softmax_attention(&q, &k, &v);
        let blocked = blockdiag_attention(&q, &k, &v, 32);
        assert!(full.max_abs_diff(&blocked) < 1e-4);
    }

    #[test]
    fn blockdiag_blocks_are_independent() {
        // Perturbing tokens in block 1 must not change block 0's output.
        let (q, k, v) = probe(64, 16, 6);
        let base = blockdiag_attention(&q, &k, &v, 32);
        let mut k2 = k.clone();
        for j in 32..64 {
            for t in 0..16 {
                k2.set(j, t, 9.9);
            }
        }
        let pert = blockdiag_attention(&q, &k2, &v, 32);
        for i in 0..32 {
            for t in 0..16 {
                assert!((base.get(i, t) - pert.get(i, t)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn newton_schulz_inverts_well_conditioned() {
        let mut rng = Pcg64::seed(7);
        // Diagonally-dominant stochastic-ish matrix: well-conditioned.
        let mut a = Mat::gaussian(16, 16, 0.05, &mut rng);
        for i in 0..16 {
            let v = a.get(i, i);
            a.set(i, i, v + 1.0);
        }
        let inv = newton_schulz_pinv(&a, 18);
        let prod = a.matmul(&inv);
        let err = prod.max_abs_diff(&Mat::eye(16));
        assert!(err < 1e-2, "err {err}");
    }

    #[test]
    fn nystrom_close_to_softmax_on_smooth_inputs() {
        // With low-rank-ish structure, Nystrom approximates SA decently.
        let mut rng = Pcg64::seed(8);
        let (q, k, v) = gaussian_qkv(64, 16, 0.3, 0.3, &mut rng);
        let exact = softmax_attention(&q, &k, &v);
        let approx = nystrom_attention(&q, &k, &v, 16);
        let denom = exact.data().iter().map(|x| x.abs()).fold(0.0, f32::max);
        assert!(exact.max_abs_diff(&approx) / denom < 0.35);
    }

    #[test]
    fn performer_approximates_softmax_rowdist() {
        // Performer's matrix should correlate with SA's on mild inputs.
        let mut rng = Pcg64::seed(9);
        let (q, k, _) = gaussian_qkv(48, 32, 0.5, 0.5, &mut rng);
        let proj = performer_projection(32, 128, 11);
        let pf = performer_attention_matrix(&q, &k, &proj);
        assert!(pf.is_stochastic(1e-3));
    }

    #[test]
    fn lln_diag_is_average_of_parts() {
        let (q, k, v) = probe(64, 16, 10);
        let combo = lln_diag_attention(&q, &k, &v, 2.0, 2.0, 32);
        let a = lln_attention(&q, &k, &v, 2.0, 2.0);
        let b = blockdiag_attention(&q, &k, &v, 32);
        for i in 0..combo.data().len() {
            let want = 0.5 * (a.data()[i] + b.data()[i]);
            assert!((combo.data()[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn linformer_reduces_context_length() {
        let (q, k, v) = probe(64, 16, 11);
        let mut rng = Pcg64::seed(12);
        let e = Mat::gaussian(64, 8, 0.1, &mut rng);
        let f = Mat::gaussian(64, 8, 0.1, &mut rng);
        let out = linformer_attention(&q, &k, &v, &e, &f);
        assert_eq!(out.shape(), (64, 16));
        assert!(out.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn clamped_exp_is_finite_at_extremes() {
        assert!(clamped_exp(1e6).is_finite());
        assert!(clamped_exp(-1e6) > 0.0);
    }
}
