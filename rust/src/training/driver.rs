//! The training driver: owns params + Adam state, steps the AOT
//! executable, and surfaces the paper's telemetry (loss, grad-norm,
//! per-layer alpha/beta/sigma stats).
//!
//! Input layout (matches aot.py `_train_io_names`):
//!   [p:* ...] [m:* ...] [v:* ...] t lr <data tensors...>
//! Output layout:
//!   [p:* ...] [m:* ...] [v:* ...] loss grad_norm layer_stats <extra...>

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::Literal;

use crate::runtime::{Engine, HostTensor, ParamStore};

/// Telemetry from one optimizer step.
#[derive(Clone, Debug)]
pub struct StepTelemetry {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    /// (L, 4): [alpha, beta, sigma_q, sigma_k] per layer (zeros for
    /// non-LLN methods).
    pub layer_stats: Vec<[f32; 4]>,
    /// (L, H, 3): [attention entropy (nats), sigma_q, sigma_k] per
    /// layer per head, probed on the batch's first sequence — the
    /// dilution diagnostic.  Empty for the AOT driver, which has no
    /// per-head readout.
    pub head_stats: Vec<Vec<[f32; 3]>>,
    /// Largest autograd tape held live during the step, in bytes
    /// (gradient checkpointing shrinks this).  0 for the AOT driver.
    pub peak_bytes: usize,
}

/// Owns model/optimizer state for one train artifact.
pub struct TrainDriver {
    pub artifact: String,
    pub model_tag: String,
    params: ParamStore,
    adam_m: ParamStore,
    adam_v: ParamStore,
    step: usize,
    n_params: usize,
    n_layers: usize,
    /// Expected data-tensor specs after the two scalars.
    data_inputs: Vec<crate::runtime::IoSpec>,
}

impl TrainDriver {
    /// `artifact` must be a `train_*` executable in the manifest.
    pub fn new(engine: &Engine, dir: &Path, artifact: &str) -> Result<Self> {
        let spec = engine.manifest().artifact(artifact)?.clone();
        let model_tag = spec
            .meta
            .get("model")
            .ok_or_else(|| anyhow!("{artifact}: no model tag in meta"))?
            .clone();
        let model = engine.manifest().model(&model_tag)?.clone();
        let n_params = model.param_order.len();

        // Sanity: the input layout must be 3 state blocks + t + lr + data.
        let expect_prefix = 3 * n_params + 2;
        if spec.inputs.len() <= expect_prefix {
            bail!("{artifact}: {} inputs, expected > {}", spec.inputs.len(), expect_prefix);
        }
        for (i, name) in model.param_order.iter().enumerate() {
            if spec.inputs[i].name != format!("p:{name}") {
                bail!("{artifact}: input {i} is {}, expected p:{name}", spec.inputs[i].name);
            }
        }
        let data_inputs = spec.inputs[expect_prefix..].to_vec();
        let n_layers = model
            .config
            .get("n_layers")
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);

        let params = ParamStore::load_initial(dir, &model)?;
        let adam_m = ParamStore::zeros_like(&params);
        let adam_v = ParamStore::zeros_like(&params);
        Ok(Self {
            artifact: artifact.to_string(),
            model_tag,
            params,
            adam_m,
            adam_v,
            step: 0,
            n_params,
            n_layers,
            data_inputs,
        })
    }

    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    pub fn params_mut(&mut self) -> &mut ParamStore {
        &mut self.params
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Assemble state literals for either a train or eval call.
    fn param_literals(&self) -> Result<Vec<Literal>> {
        self.params.to_literals()
    }

    /// Execute one optimizer step.  `data` must match the artifact's
    /// trailing data tensors (tokens/labels/... in manifest order).
    pub fn step(
        &mut self,
        engine: &mut Engine,
        lr: f64,
        data: &[HostTensor],
    ) -> Result<StepTelemetry> {
        if data.len() != self.data_inputs.len() {
            bail!(
                "{}: {} data tensors, manifest wants {} ({:?})",
                self.artifact,
                data.len(),
                self.data_inputs.len(),
                self.data_inputs.iter().map(|s| s.name.clone()).collect::<Vec<_>>()
            );
        }
        for (t, spec) in data.iter().zip(&self.data_inputs) {
            if t.len() != spec.elements() {
                bail!(
                    "{}: data {} has {} elems, wants {:?}",
                    self.artifact,
                    spec.name,
                    t.len(),
                    spec.shape
                );
            }
        }
        let mut inputs = Vec::with_capacity(3 * self.n_params + 2 + data.len());
        inputs.extend(self.param_literals()?);
        inputs.extend(self.adam_m.to_literals()?);
        inputs.extend(self.adam_v.to_literals()?);
        let t = (self.step + 1) as f32; // Adam bias-correction counter (1-based)
        inputs.push(HostTensor::scalar_f32(t).to_literal()?);
        inputs.push(HostTensor::scalar_f32(lr as f32).to_literal()?);
        for d in data {
            inputs.push(d.to_literal()?);
        }

        let outputs = engine.execute_literals(&self.artifact, &inputs)?;
        let want = 3 * self.n_params + 3;
        if outputs.len() < want {
            bail!("{}: {} outputs, expected >= {}", self.artifact, outputs.len(), want);
        }
        self.params.update_from_literals(&outputs[..self.n_params])?;
        self.adam_m.update_from_literals(&outputs[self.n_params..2 * self.n_params])?;
        self.adam_v.update_from_literals(&outputs[2 * self.n_params..3 * self.n_params])?;

        let loss = outputs[3 * self.n_params]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let grad_norm = outputs[3 * self.n_params + 1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("grad_norm: {e:?}"))?[0];
        let stats_raw = outputs[3 * self.n_params + 2]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("layer_stats: {e:?}"))?;
        let mut layer_stats = Vec::with_capacity(self.n_layers);
        for chunk in stats_raw.chunks_exact(4) {
            layer_stats.push([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        self.step += 1;
        if !loss.is_finite() {
            bail!("{}: non-finite loss at step {}", self.artifact, self.step);
        }
        Ok(StepTelemetry {
            step: self.step,
            loss,
            grad_norm,
            layer_stats,
            head_stats: Vec::new(),
            peak_bytes: 0,
        })
    }

    /// Run the matching eval artifact (train_ -> eval_ naming convention)
    /// with the current parameters + given data; returns its outputs.
    pub fn eval(&self, engine: &mut Engine, data: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let eval_name = self.artifact.replacen("train", "eval", 1);
        let spec = engine.manifest().artifact(&eval_name)?.clone();
        let mut inputs = self.param_literals()?;
        for d in data {
            inputs.push(d.to_literal()?);
        }
        if inputs.len() != spec.inputs.len() {
            bail!("{eval_name}: {} inputs vs manifest {}", inputs.len(), spec.inputs.len());
        }
        let outputs = engine.execute_literals(&eval_name, &inputs)?;
        outputs
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| HostTensor::from_literal(lit, ospec).context(eval_name.clone()))
            .collect()
    }

    /// Write the current parameters as a checkpoint.  Atomic via
    /// [`ParamStore::save`]'s temp-file + rename commit: a crash
    /// mid-write never corrupts an existing checkpoint at `path`.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.params.save(path)
    }
}

/// Argmax-accuracy helper for classification eval outputs.
pub fn accuracy_from_logits(logits: &[f32], labels: &[i32], num_classes: usize) -> f64 {
    let n = labels.len();
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let row = &logits[i * num_classes..(i + 1) * num_classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0);
        if pred == label as usize {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;
    use crate::runtime::{artifacts_available, artifacts_dir};

    #[test]
    fn accuracy_helper() {
        let logits = vec![0.1, 0.9, 0.8, 0.2, 0.3, 0.7];
        let labels = vec![1, 0, 0];
        let acc = accuracy_from_logits(&logits, &labels, 2);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn tiny_mlm_training_reduces_loss() {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return;
        }
        let mut engine = Engine::new(&dir).unwrap();
        let mut driver = TrainDriver::new(&engine, &dir, "train_tinymlm_lln_diag").unwrap();
        let mut corpus = Corpus::new(512, 42);
        let mut first = None;
        let mut last = 0.0f32;
        for step in 0..20 {
            let b = corpus.mlm_batch(4, 128, 0.15);
            let data = [
                HostTensor::I32 { shape: vec![4, 128], data: b.tokens },
                HostTensor::I32 { shape: vec![4, 128], data: b.labels },
                HostTensor::F32 { shape: vec![4, 128], data: b.weights },
            ];
            let out = driver.step(&mut engine, 3e-3, &data).unwrap();
            assert!(out.loss.is_finite() && out.grad_norm.is_finite());
            if step == 0 {
                first = Some(out.loss);
            }
            last = out.loss;
        }
        let first = first.unwrap();
        assert!(
            last < first - 0.35,
            "loss should drop: first={first} last={last}"
        );
    }

    #[test]
    fn lln_driver_emits_alpha_beta_stats() {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return;
        }
        let mut engine = Engine::new(&dir).unwrap();
        let mut driver = TrainDriver::new(&engine, &dir, "train_tinymlm_lln").unwrap();
        let mut corpus = Corpus::new(512, 7);
        let b = corpus.mlm_batch(4, 128, 0.15);
        let data = [
            HostTensor::I32 { shape: vec![4, 128], data: b.tokens },
            HostTensor::I32 { shape: vec![4, 128], data: b.labels },
            HostTensor::F32 { shape: vec![4, 128], data: b.weights },
        ];
        let out = driver.step(&mut engine, 1e-3, &data).unwrap();
        assert_eq!(out.layer_stats.len(), 2); // tiny = 2 layers
        for s in &out.layer_stats {
            // At init sigma_q is tiny (~0.15), so eq. 10 legitimately
            // produces alpha >> the trained-equilibrium ~2.2 of fig. 9.
            // The meaningful invariants: positive, finite, and the
            // product alpha*sigma_q (the feature-map exponent scale)
            // stays moderate.
            assert!(s[0] > 0.5 && s[0].is_finite(), "alpha {s:?}");
            assert!(s[2] > 0.0, "sigma_q {s:?}");
            let exponent_scale = s[0] * s[2];
            assert!(exponent_scale < 5.0, "alpha*sigma_q too hot: {s:?}");
        }
    }

    #[test]
    fn driver_rejects_bad_data_arity() {
        let dir = artifacts_dir(None);
        if !artifacts_available(&dir) {
            return;
        }
        let mut engine = Engine::new(&dir).unwrap();
        let mut driver = TrainDriver::new(&engine, &dir, "train_tinymlm_softmax").unwrap();
        let err = driver.step(&mut engine, 1e-3, &[]).unwrap_err();
        assert!(format!("{err}").contains("data tensors"));
    }
}
